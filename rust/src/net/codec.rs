//! Hand-rolled binary wire codec for the network serving front end.
//!
//! Like the CLI parser, the codec vendors nothing: every frame is a fixed
//! 20-byte header followed by a little-endian payload, written and parsed
//! with checked readers that can never over-read or panic on hostile
//! input — malformed bytes come back as a [`CodecError`], period.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//!      0     4  magic        0x5049_4D31 ("PIM1")
//!      4     2  version      protocol version (currently 1)
//!      6     2  kind         0 = request, 1 = response
//!      8     8  corr         correlation id, echoed on the reply
//!     16     4  payload_len  bytes that follow (<= 1 MiB)
//!     20     …  payload      one encoded NetRequest / NetResponse
//! ```
//!
//! Responses stream back out-of-order; the correlation id is what ties a
//! reply to its request, so a slow read-back never head-of-line-blocks
//! the connection.

use std::io::Read;

use crate::coordinator::QosClass;
use crate::pim::{CommandCensus, PimOp};
use crate::util::{BitRow, ShiftDir};

/// Frame magic: "PIM1" as a little-endian u32.
pub const MAGIC: u32 = 0x5049_4d31;
/// Protocol version spoken by this build (checked in `Hello`/`Welcome`).
pub const PROTO_VERSION: u16 = 1;
/// Fixed frame-header size in bytes.
pub const HEADER_LEN: usize = 20;
/// Hard cap on a frame payload; larger claims are rejected unread.
pub const MAX_PAYLOAD: usize = 1 << 20;
/// Cap on handles per `Alloc`/`Free`/`SubmitKernel`.
pub const MAX_HANDLES: usize = 4096;
/// Cap on macro-ops per submitted kernel.
pub const MAX_OPS: usize = 65_536;
/// Cap on an error-message string.
const MAX_STRING: usize = 4096;

/// Error-code namespace for [`NetResponse::Error`].
pub const ERR_PROTOCOL: u16 = 1;
/// The request was well-formed but the PIM system rejected it.
pub const ERR_PIM: u16 = 2;
/// The request named a handle this session does not own.
pub const ERR_UNKNOWN_HANDLE: u16 = 3;

/// Everything that can go wrong turning bytes into frames. Decoding is
/// total: hostile input maps onto one of these, never a panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// Header magic was not `PIM1`.
    BadMagic,
    /// Header version field did not match [`PROTO_VERSION`].
    BadVersion(u16),
    /// Header kind field was neither request nor response.
    BadKind(u16),
    /// Claimed payload length exceeds [`MAX_PAYLOAD`].
    Oversized(u32),
    /// The stream ended (or the buffer ran out) mid-frame.
    Truncated,
    /// Unknown message or op tag.
    BadTag(u8),
    /// Payload bytes left over after a complete message.
    Trailing,
    /// A field value was out of range (the str names the field).
    BadValue(&'static str),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::BadMagic => write!(f, "bad frame magic"),
            CodecError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            CodecError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            CodecError::Oversized(n) => write!(f, "payload length {n} exceeds {MAX_PAYLOAD}"),
            CodecError::Truncated => write!(f, "truncated frame"),
            CodecError::BadTag(t) => write!(f, "unknown message tag {t}"),
            CodecError::Trailing => write!(f, "trailing bytes after message"),
            CodecError::BadValue(what) => write!(f, "bad field value: {what}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// Which side of the protocol a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    Request,
    Response,
}

impl FrameKind {
    fn to_u16(self) -> u16 {
        match self {
            FrameKind::Request => 0,
            FrameKind::Response => 1,
        }
    }

    fn from_u16(v: u16) -> Result<Self, CodecError> {
        match v {
            0 => Ok(FrameKind::Request),
            1 => Ok(FrameKind::Response),
            other => Err(CodecError::BadKind(other)),
        }
    }
}

/// One parsed frame: header fields plus the raw payload, ready for
/// [`decode_request`] / [`decode_response`].
#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub corr: u64,
    pub payload: Vec<u8>,
}

/// A row handle as it crosses the wire: the session-local `(slot, gen)`
/// pair. The server resolves it against the connection's own handle
/// table, so one session can never name another session's rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WireHandle {
    pub slot: u32,
    pub gen: u32,
}

/// Session verbs a client sends. `Hello` must come first; everything
/// else is rejected until the handshake completes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NetRequest {
    /// Handshake: the client's protocol version, plus an optional QoS
    /// class for the whole session. `None` (and the original 3-byte
    /// payload, which older clients still send) means the server's
    /// default class — a protocol-minor extension, not a version bump.
    Hello { proto: u16, qos: Option<QosClass> },
    /// Allocate `n` rows on the session's bank.
    Alloc { n: u32 },
    /// Free previously allocated rows.
    Free { handles: Vec<WireHandle> },
    /// Write a full row of bits.
    WriteRow { handle: WireHandle, bits: BitRow },
    /// Read a full row back.
    ReadRow { handle: WireHandle },
    /// Submit a whole kernel bound to the listed handle rows.
    SubmitKernel { ops: Vec<PimOp>, handles: Vec<WireHandle> },
    /// Snapshot the server's network counters.
    Stats,
    /// Clean goodbye: drain pending replies, then close.
    Goodbye,
}

/// Snapshot of the server's [`NetCounters`] carried by
/// [`NetResponse::Stats`].
///
/// [`NetCounters`]: crate::coordinator::NetCounters
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    pub connections: u64,
    pub open: u64,
    pub frames: u64,
    pub busy_rejects: u64,
    pub timeouts: u64,
    pub reaped: u64,
    pub malformed: u64,
    /// Admission-control sheds per QoS class. Encoded after the original
    /// seven counters; a peer speaking the pre-QoS minor omits them and
    /// decodes to zero (see [`decode_response`]).
    pub shed_latency: u64,
    pub shed_throughput: u64,
    pub shed_background: u64,
}

/// Replies the server streams back, matched to requests by correlation
/// id (out-of-order is normal).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NetResponse {
    /// Handshake accepted: server protocol version, row width in bits,
    /// the bank this session landed on, and the inflight cap.
    Welcome { proto: u16, cols: u32, bank: u32, max_inflight: u32 },
    /// Rows allocated by `Alloc`.
    Allocated { handles: Vec<WireHandle> },
    /// How many handles `Free` actually released.
    Freed { n: u32 },
    /// A `WriteRow` completed.
    Done,
    /// A `ReadRow` result.
    Row { bits: BitRow },
    /// A `SubmitKernel` receipt: command census + elided AAPs.
    Ran { census: CommandCensus, elided_aaps: u64 },
    /// Counter snapshot for `Stats`.
    Stats(WireStats),
    /// Acknowledges `Goodbye`; the server closes after sending it.
    Bye,
    /// Backpressure: the connection is at its inflight cap. The request
    /// was NOT enqueued — retry after a reply drains.
    Busy { inflight: u32, cap: u32 },
    /// The request failed; `code` is one of the `ERR_*` constants.
    Error { code: u16, message: String },
}

// ---------------------------------------------------------------------
// checked little-endian reader / writer
// ---------------------------------------------------------------------

struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, CodecError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Every decode ends here: leftover bytes are a protocol error, not
    /// something to silently ignore.
    fn finish(self) -> Result<(), CodecError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(CodecError::Trailing)
        }
    }
}

#[derive(Default)]
struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn try_u32(v: usize, what: &'static str) -> Result<u32, CodecError> {
    u32::try_from(v).map_err(|_| CodecError::BadValue(what))
}

// ---------------------------------------------------------------------
// field codecs
// ---------------------------------------------------------------------

fn put_handle(w: &mut ByteWriter, h: &WireHandle) {
    w.u32(h.slot);
    w.u32(h.gen);
}

fn get_handle(r: &mut ByteReader) -> Result<WireHandle, CodecError> {
    Ok(WireHandle { slot: r.u32()?, gen: r.u32()? })
}

fn put_handles(w: &mut ByteWriter, hs: &[WireHandle]) -> Result<(), CodecError> {
    if hs.len() > MAX_HANDLES {
        return Err(CodecError::BadValue("too many handles"));
    }
    w.u32(try_u32(hs.len(), "handle count")?);
    for h in hs {
        put_handle(w, h);
    }
    Ok(())
}

fn get_handles(r: &mut ByteReader) -> Result<Vec<WireHandle>, CodecError> {
    let n = r.u32()? as usize;
    if n > MAX_HANDLES {
        return Err(CodecError::BadValue("too many handles"));
    }
    if r.remaining() < n * 8 {
        return Err(CodecError::Truncated);
    }
    let mut hs = Vec::with_capacity(n);
    for _ in 0..n {
        hs.push(get_handle(r)?);
    }
    Ok(hs)
}

fn put_row(w: &mut ByteWriter, bits: &BitRow) -> Result<(), CodecError> {
    if bits.is_empty() {
        return Err(CodecError::BadValue("empty row"));
    }
    w.u32(try_u32(bits.len(), "row length")?);
    for word in bits.words() {
        w.u64(*word);
    }
    Ok(())
}

fn get_row(r: &mut ByteReader) -> Result<BitRow, CodecError> {
    let len = r.u32()? as usize;
    if len == 0 {
        return Err(CodecError::BadValue("empty row"));
    }
    let words = len.div_ceil(64);
    if r.remaining() < words * 8 {
        return Err(CodecError::Truncated);
    }
    let mut row = BitRow::zeros(len);
    for slot in row.words_mut() {
        *slot = r.u64()?;
    }
    let tail = len % 64;
    if tail != 0 && row.words().last().is_some_and(|w| w >> tail != 0) {
        return Err(CodecError::BadValue("row tail bits set beyond len"));
    }
    Ok(row)
}

fn put_op(w: &mut ByteWriter, op: &PimOp) -> Result<(), CodecError> {
    let slot = |v: usize| try_u32(v, "op row slot");
    match *op {
        PimOp::Copy { src, dst } => {
            w.u8(0);
            w.u32(slot(src)?);
            w.u32(slot(dst)?);
        }
        PimOp::SetZero { dst } => {
            w.u8(1);
            w.u32(slot(dst)?);
        }
        PimOp::SetOnes { dst } => {
            w.u8(2);
            w.u32(slot(dst)?);
        }
        PimOp::Not { src, dst } => {
            w.u8(3);
            w.u32(slot(src)?);
            w.u32(slot(dst)?);
        }
        PimOp::And { a, b, dst } => {
            w.u8(4);
            w.u32(slot(a)?);
            w.u32(slot(b)?);
            w.u32(slot(dst)?);
        }
        PimOp::Or { a, b, dst } => {
            w.u8(5);
            w.u32(slot(a)?);
            w.u32(slot(b)?);
            w.u32(slot(dst)?);
        }
        PimOp::Maj { a, b, c, dst } => {
            w.u8(6);
            w.u32(slot(a)?);
            w.u32(slot(b)?);
            w.u32(slot(c)?);
            w.u32(slot(dst)?);
        }
        PimOp::Xor { a, b, dst } => {
            w.u8(7);
            w.u32(slot(a)?);
            w.u32(slot(b)?);
            w.u32(slot(dst)?);
        }
        PimOp::ShiftRight { src, dst } => {
            w.u8(8);
            w.u32(slot(src)?);
            w.u32(slot(dst)?);
        }
        PimOp::ShiftLeft { src, dst } => {
            w.u8(9);
            w.u32(slot(src)?);
            w.u32(slot(dst)?);
        }
        PimOp::ShiftBy { src, dst, n, dir } => {
            w.u8(10);
            w.u32(slot(src)?);
            w.u32(slot(dst)?);
            w.u32(try_u32(n, "shift amount")?);
            w.u8(match dir {
                ShiftDir::Right => 0,
                ShiftDir::Left => 1,
            });
        }
    }
    Ok(())
}

fn get_op(r: &mut ByteReader) -> Result<PimOp, CodecError> {
    let tag = r.u8()?;
    let op = match tag {
        0 => PimOp::Copy { src: r.u32()? as usize, dst: r.u32()? as usize },
        1 => PimOp::SetZero { dst: r.u32()? as usize },
        2 => PimOp::SetOnes { dst: r.u32()? as usize },
        3 => PimOp::Not { src: r.u32()? as usize, dst: r.u32()? as usize },
        4 => PimOp::And { a: r.u32()? as usize, b: r.u32()? as usize, dst: r.u32()? as usize },
        5 => PimOp::Or { a: r.u32()? as usize, b: r.u32()? as usize, dst: r.u32()? as usize },
        6 => PimOp::Maj {
            a: r.u32()? as usize,
            b: r.u32()? as usize,
            c: r.u32()? as usize,
            dst: r.u32()? as usize,
        },
        7 => PimOp::Xor { a: r.u32()? as usize, b: r.u32()? as usize, dst: r.u32()? as usize },
        8 => PimOp::ShiftRight { src: r.u32()? as usize, dst: r.u32()? as usize },
        9 => PimOp::ShiftLeft { src: r.u32()? as usize, dst: r.u32()? as usize },
        10 => {
            let src = r.u32()? as usize;
            let dst = r.u32()? as usize;
            let n = r.u32()? as usize;
            let dir = match r.u8()? {
                0 => ShiftDir::Right,
                1 => ShiftDir::Left,
                _ => return Err(CodecError::BadValue("shift direction")),
            };
            PimOp::ShiftBy { src, dst, n, dir }
        }
        other => return Err(CodecError::BadTag(other)),
    };
    Ok(op)
}

fn put_ops(w: &mut ByteWriter, ops: &[PimOp]) -> Result<(), CodecError> {
    if ops.len() > MAX_OPS {
        return Err(CodecError::BadValue("too many ops"));
    }
    w.u32(try_u32(ops.len(), "op count")?);
    for op in ops {
        put_op(w, op)?;
    }
    Ok(())
}

fn get_ops(r: &mut ByteReader) -> Result<Vec<PimOp>, CodecError> {
    let n = r.u32()? as usize;
    if n > MAX_OPS {
        return Err(CodecError::BadValue("too many ops"));
    }
    // every op is at least 5 bytes (tag + one u32 field)
    if r.remaining() < n * 5 {
        return Err(CodecError::Truncated);
    }
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        ops.push(get_op(r)?);
    }
    Ok(ops)
}

fn put_string(w: &mut ByteWriter, s: &str) -> Result<(), CodecError> {
    if s.len() > MAX_STRING {
        return Err(CodecError::BadValue("string too long"));
    }
    w.u32(try_u32(s.len(), "string length")?);
    w.buf.extend_from_slice(s.as_bytes());
    Ok(())
}

fn get_string(r: &mut ByteReader) -> Result<String, CodecError> {
    let n = r.u32()? as usize;
    if n > MAX_STRING {
        return Err(CodecError::BadValue("string too long"));
    }
    let bytes = r.take(n)?;
    String::from_utf8(bytes.to_vec()).map_err(|_| CodecError::BadValue("string not utf-8"))
}

fn put_census(w: &mut ByteWriter, c: &CommandCensus) {
    w.u64(c.act);
    w.u64(c.pre);
    w.u64(c.read);
    w.u64(c.write);
    w.u64(c.aap);
    w.u64(c.dra);
    w.u64(c.tra);
    w.u64(c.refresh);
}

fn get_census(r: &mut ByteReader) -> Result<CommandCensus, CodecError> {
    Ok(CommandCensus {
        act: r.u64()?,
        pre: r.u64()?,
        read: r.u64()?,
        write: r.u64()?,
        aap: r.u64()?,
        dra: r.u64()?,
        tra: r.u64()?,
        refresh: r.u64()?,
    })
}

// ---------------------------------------------------------------------
// message payloads
// ---------------------------------------------------------------------

fn encode_request_payload(req: &NetRequest) -> Result<Vec<u8>, CodecError> {
    let mut w = ByteWriter::default();
    match req {
        NetRequest::Hello { proto, qos } => {
            w.u8(0);
            w.u16(*proto);
            // minor extension: the class byte is only present when the
            // client opts into a non-default session class
            if let Some(class) = qos {
                w.u8(class.index() as u8);
            }
        }
        NetRequest::Alloc { n } => {
            w.u8(1);
            w.u32(*n);
        }
        NetRequest::Free { handles } => {
            w.u8(2);
            put_handles(&mut w, handles)?;
        }
        NetRequest::WriteRow { handle, bits } => {
            w.u8(3);
            put_handle(&mut w, handle);
            put_row(&mut w, bits)?;
        }
        NetRequest::ReadRow { handle } => {
            w.u8(4);
            put_handle(&mut w, handle);
        }
        NetRequest::SubmitKernel { ops, handles } => {
            w.u8(5);
            put_ops(&mut w, ops)?;
            put_handles(&mut w, handles)?;
        }
        NetRequest::Stats => w.u8(6),
        NetRequest::Goodbye => w.u8(7),
    }
    Ok(w.buf)
}

/// Decode a request payload (the bytes after the frame header).
pub fn decode_request(payload: &[u8]) -> Result<NetRequest, CodecError> {
    let mut r = ByteReader::new(payload);
    let req = match r.u8()? {
        0 => {
            let proto = r.u16()?;
            let qos = if r.remaining() > 0 {
                let b = r.u8()?;
                Some(
                    QosClass::from_index(b as usize)
                        .ok_or(CodecError::BadValue("qos class"))?,
                )
            } else {
                None
            };
            NetRequest::Hello { proto, qos }
        }
        1 => {
            let n = r.u32()?;
            if n == 0 || n as usize > MAX_HANDLES {
                return Err(CodecError::BadValue("alloc count"));
            }
            NetRequest::Alloc { n }
        }
        2 => NetRequest::Free { handles: get_handles(&mut r)? },
        3 => NetRequest::WriteRow { handle: get_handle(&mut r)?, bits: get_row(&mut r)? },
        4 => NetRequest::ReadRow { handle: get_handle(&mut r)? },
        5 => NetRequest::SubmitKernel { ops: get_ops(&mut r)?, handles: get_handles(&mut r)? },
        6 => NetRequest::Stats,
        7 => NetRequest::Goodbye,
        other => return Err(CodecError::BadTag(other)),
    };
    r.finish()?;
    Ok(req)
}

fn encode_response_payload(resp: &NetResponse) -> Result<Vec<u8>, CodecError> {
    let mut w = ByteWriter::default();
    match resp {
        NetResponse::Welcome { proto, cols, bank, max_inflight } => {
            w.u8(0);
            w.u16(*proto);
            w.u32(*cols);
            w.u32(*bank);
            w.u32(*max_inflight);
        }
        NetResponse::Allocated { handles } => {
            w.u8(1);
            put_handles(&mut w, handles)?;
        }
        NetResponse::Freed { n } => {
            w.u8(2);
            w.u32(*n);
        }
        NetResponse::Done => w.u8(3),
        NetResponse::Row { bits } => {
            w.u8(4);
            put_row(&mut w, bits)?;
        }
        NetResponse::Ran { census, elided_aaps } => {
            w.u8(5);
            put_census(&mut w, census);
            w.u64(*elided_aaps);
        }
        NetResponse::Stats(s) => {
            w.u8(6);
            w.u64(s.connections);
            w.u64(s.open);
            w.u64(s.frames);
            w.u64(s.busy_rejects);
            w.u64(s.timeouts);
            w.u64(s.reaped);
            w.u64(s.malformed);
            w.u64(s.shed_latency);
            w.u64(s.shed_throughput);
            w.u64(s.shed_background);
        }
        NetResponse::Bye => w.u8(7),
        NetResponse::Busy { inflight, cap } => {
            w.u8(8);
            w.u32(*inflight);
            w.u32(*cap);
        }
        NetResponse::Error { code, message } => {
            w.u8(9);
            w.u16(*code);
            put_string(&mut w, message)?;
        }
    }
    Ok(w.buf)
}

/// Decode a response payload (the bytes after the frame header).
pub fn decode_response(payload: &[u8]) -> Result<NetResponse, CodecError> {
    let mut r = ByteReader::new(payload);
    let resp = match r.u8()? {
        0 => NetResponse::Welcome {
            proto: r.u16()?,
            cols: r.u32()?,
            bank: r.u32()?,
            max_inflight: r.u32()?,
        },
        1 => NetResponse::Allocated { handles: get_handles(&mut r)? },
        2 => NetResponse::Freed { n: r.u32()? },
        3 => NetResponse::Done,
        4 => NetResponse::Row { bits: get_row(&mut r)? },
        5 => NetResponse::Ran { census: get_census(&mut r)?, elided_aaps: r.u64()? },
        6 => {
            let mut s = WireStats {
                connections: r.u64()?,
                open: r.u64()?,
                frames: r.u64()?,
                busy_rejects: r.u64()?,
                timeouts: r.u64()?,
                reaped: r.u64()?,
                malformed: r.u64()?,
                ..WireStats::default()
            };
            // pre-QoS minor: the three shed counters may be absent
            if r.remaining() > 0 {
                s.shed_latency = r.u64()?;
                s.shed_throughput = r.u64()?;
                s.shed_background = r.u64()?;
            }
            NetResponse::Stats(s)
        }
        7 => NetResponse::Bye,
        8 => NetResponse::Busy { inflight: r.u32()?, cap: r.u32()? },
        9 => NetResponse::Error { code: r.u16()?, message: get_string(&mut r)? },
        other => return Err(CodecError::BadTag(other)),
    };
    r.finish()?;
    Ok(resp)
}

// ---------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------

fn encode_frame(kind: FrameKind, corr: u64, payload: Vec<u8>) -> Result<Vec<u8>, CodecError> {
    if payload.len() > MAX_PAYLOAD {
        return Err(CodecError::Oversized(payload.len() as u32));
    }
    let mut w = ByteWriter { buf: Vec::with_capacity(HEADER_LEN + payload.len()) };
    w.u32(MAGIC);
    w.u16(PROTO_VERSION);
    w.u16(kind.to_u16());
    w.u64(corr);
    w.u32(payload.len() as u32);
    w.buf.extend_from_slice(&payload);
    Ok(w.buf)
}

/// Encode one request as a complete frame (header + payload).
pub fn encode_request(corr: u64, req: &NetRequest) -> Result<Vec<u8>, CodecError> {
    encode_frame(FrameKind::Request, corr, encode_request_payload(req)?)
}

/// Encode one response as a complete frame (header + payload).
pub fn encode_response(corr: u64, resp: &NetResponse) -> Result<Vec<u8>, CodecError> {
    encode_frame(FrameKind::Response, corr, encode_response_payload(resp)?)
}

fn parse_header(buf: &[u8]) -> Result<(FrameKind, u64, usize), CodecError> {
    let mut r = ByteReader::new(buf);
    if r.u32()? != MAGIC {
        return Err(CodecError::BadMagic);
    }
    let version = r.u16()?;
    if version != PROTO_VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let kind = FrameKind::from_u16(r.u16()?)?;
    let corr = r.u64()?;
    let len = r.u32()?;
    if len as usize > MAX_PAYLOAD {
        return Err(CodecError::Oversized(len));
    }
    Ok((kind, corr, len as usize))
}

/// What one [`FrameReader::poll`] call produced.
#[derive(Debug)]
pub enum FramePoll {
    /// A complete frame arrived.
    Frame(Frame),
    /// The read would block / timed out; call again later. Any partial
    /// frame stays buffered, so timeouts mid-frame lose nothing.
    Idle,
    /// The peer closed cleanly at a frame boundary.
    Eof,
}

/// A frame-read failure: transport-level or protocol-level.
#[derive(Debug)]
pub enum ReadError {
    Io(std::io::Error),
    Codec(CodecError),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Io(e) => write!(f, "io error: {e}"),
            ReadError::Codec(e) => write!(f, "codec error: {e}"),
        }
    }
}

impl std::error::Error for ReadError {}

/// Incremental frame parser over any [`Read`]. Designed for sockets with
/// a read timeout: a timeout mid-frame returns [`FramePoll::Idle`] and
/// keeps the partial bytes, so the caller can tick its idle/stop checks
/// and resume without losing stream alignment.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pull bytes until a full frame, a quiet period, EOF, or an error.
    pub fn poll(&mut self, r: &mut impl Read) -> Result<FramePoll, ReadError> {
        let mut chunk = [0u8; 4096];
        loop {
            if self.buf.len() >= HEADER_LEN {
                let (kind, corr, len) =
                    parse_header(&self.buf[..HEADER_LEN]).map_err(ReadError::Codec)?;
                if self.buf.len() >= HEADER_LEN + len {
                    let payload = self.buf[HEADER_LEN..HEADER_LEN + len].to_vec();
                    self.buf.drain(..HEADER_LEN + len);
                    return Ok(FramePoll::Frame(Frame { kind, corr, payload }));
                }
            }
            match r.read(&mut chunk) {
                Ok(0) => {
                    return if self.buf.is_empty() {
                        Ok(FramePoll::Eof)
                    } else {
                        Err(ReadError::Codec(CodecError::Truncated))
                    };
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(FramePoll::Idle);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(ReadError::Io(e)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip_req(req: &NetRequest) -> NetRequest {
        let bytes = encode_request(7, req).unwrap();
        let mut reader = FrameReader::new();
        let mut src = &bytes[..];
        match reader.poll(&mut src).unwrap() {
            FramePoll::Frame(f) => {
                assert_eq!(f.kind, FrameKind::Request);
                assert_eq!(f.corr, 7);
                decode_request(&f.payload).unwrap()
            }
            other => panic!("expected frame, got {other:?}"),
        }
    }

    #[test]
    fn request_roundtrip() {
        let mut rng = Rng::new(0xC0DEC);
        let reqs = vec![
            NetRequest::Hello { proto: PROTO_VERSION, qos: None },
            NetRequest::Hello { proto: PROTO_VERSION, qos: Some(QosClass::Latency) },
            NetRequest::Hello { proto: PROTO_VERSION, qos: Some(QosClass::Background) },
            NetRequest::Alloc { n: 3 },
            NetRequest::Free {
                handles: vec![WireHandle { slot: 1, gen: 0 }, WireHandle { slot: 9, gen: 4 }],
            },
            NetRequest::WriteRow {
                handle: WireHandle { slot: 2, gen: 1 },
                bits: BitRow::random(100, &mut rng),
            },
            NetRequest::ReadRow { handle: WireHandle { slot: 2, gen: 1 } },
            NetRequest::SubmitKernel {
                ops: vec![
                    PimOp::ShiftBy { src: 0, dst: 0, n: 3, dir: ShiftDir::Left },
                    PimOp::Xor { a: 0, b: 1, dst: 2 },
                ],
                handles: vec![WireHandle { slot: 0, gen: 0 }],
            },
            NetRequest::Stats,
            NetRequest::Goodbye,
        ];
        for req in &reqs {
            assert_eq!(&roundtrip_req(req), req);
        }
    }

    #[test]
    fn response_roundtrip() {
        let mut rng = Rng::new(0xFACE);
        let resps = vec![
            NetResponse::Welcome { proto: 1, cols: 256, bank: 3, max_inflight: 64 },
            NetResponse::Allocated { handles: vec![WireHandle { slot: 5, gen: 2 }] },
            NetResponse::Freed { n: 2 },
            NetResponse::Done,
            NetResponse::Row { bits: BitRow::random(256, &mut rng) },
            NetResponse::Ran {
                census: CommandCensus { act: 1, pre: 2, aap: 12, ..CommandCensus::default() },
                elided_aaps: 3,
            },
            NetResponse::Stats(WireStats {
                connections: 8,
                frames: 99,
                shed_latency: 1,
                shed_throughput: 2,
                shed_background: 7,
                ..WireStats::default()
            }),
            NetResponse::Bye,
            NetResponse::Busy { inflight: 64, cap: 64 },
            NetResponse::Error { code: ERR_PIM, message: "stale handle".into() },
        ];
        for resp in &resps {
            let bytes = encode_response(42, resp).unwrap();
            let (kind, corr, len) = parse_header(&bytes[..HEADER_LEN]).unwrap();
            assert_eq!(kind, FrameKind::Response);
            assert_eq!(corr, 42);
            assert_eq!(len, bytes.len() - HEADER_LEN);
            assert_eq!(&decode_response(&bytes[HEADER_LEN..]).unwrap(), resp);
        }
    }

    #[test]
    fn short_hello_decodes_as_default_class() {
        // a pre-QoS peer sends the original 3-byte Hello payload: tag,
        // proto u16, nothing else — it must still decode, with qos None
        let mut w = ByteWriter::default();
        w.u8(0);
        w.u16(PROTO_VERSION);
        assert_eq!(
            decode_request(&w.buf),
            Ok(NetRequest::Hello { proto: PROTO_VERSION, qos: None })
        );
    }

    #[test]
    fn bad_qos_byte_rejected() {
        let mut w = ByteWriter::default();
        w.u8(0);
        w.u16(PROTO_VERSION);
        w.u8(3); // only 0/1/2 are classes
        assert_eq!(decode_request(&w.buf), Err(CodecError::BadValue("qos class")));
    }

    #[test]
    fn short_stats_decodes_with_zero_sheds() {
        // a pre-QoS server encodes 7 counters; the shed fields read as 0
        let mut w = ByteWriter::default();
        w.u8(6);
        for v in [4u64, 2, 100, 3, 0, 1, 5] {
            w.u64(v);
        }
        let got = decode_response(&w.buf).unwrap();
        assert_eq!(
            got,
            NetResponse::Stats(WireStats {
                connections: 4,
                open: 2,
                frames: 100,
                busy_rejects: 3,
                timeouts: 0,
                reaped: 1,
                malformed: 5,
                shed_latency: 0,
                shed_throughput: 0,
                shed_background: 0,
            })
        );
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode_request(0, &NetRequest::Stats).unwrap();
        bytes[0] ^= 0xff;
        let mut reader = FrameReader::new();
        match reader.poll(&mut &bytes[..]) {
            Err(ReadError::Codec(CodecError::BadMagic)) => {}
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn truncation_never_panics() {
        let bytes = encode_request(
            1,
            &NetRequest::SubmitKernel {
                ops: vec![PimOp::Maj { a: 0, b: 1, c: 2, dst: 3 }],
                handles: vec![WireHandle { slot: 0, gen: 0 }],
            },
        )
        .unwrap();
        for cut in 0..bytes.len() {
            let mut reader = FrameReader::new();
            match reader.poll(&mut &bytes[..cut]) {
                Ok(FramePoll::Eof) if cut == 0 => {}
                Err(ReadError::Codec(CodecError::Truncated)) => {}
                other => panic!("cut at {cut}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_claim_rejected_unread() {
        let mut bytes = encode_request(0, &NetRequest::Stats).unwrap();
        bytes[16..20].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        let mut reader = FrameReader::new();
        match reader.poll(&mut &bytes[..]) {
            Err(ReadError::Codec(CodecError::Oversized(_))) => {}
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut payload = encode_request_payload(&NetRequest::Stats).unwrap();
        payload.push(0);
        assert_eq!(decode_request(&payload), Err(CodecError::Trailing));
    }

    #[test]
    fn split_delivery_reassembles() {
        let bytes = encode_request(9, &NetRequest::Alloc { n: 2 }).unwrap();
        let mut reader = FrameReader::new();
        let (a, b) = bytes.split_at(HEADER_LEN + 1);
        match reader.poll(&mut &a[..]) {
            // one Read source: EOF mid-frame surfaces after buffering,
            // so feed the rest before judging
            Err(ReadError::Codec(CodecError::Truncated)) => {}
            other => panic!("expected Truncated on first half, got {other:?}"),
        }
        let mut reader = FrameReader::new();
        let mut joined = a.to_vec();
        joined.extend_from_slice(b);
        match reader.poll(&mut &joined[..]).unwrap() {
            FramePoll::Frame(f) => {
                assert_eq!(decode_request(&f.payload).unwrap(), NetRequest::Alloc { n: 2 });
            }
            other => panic!("expected frame, got {other:?}"),
        }
    }
}
