//! The socket front end: accept loops for TCP and Unix-domain listeners,
//! one connection thread per accepted stream, and a shutdown path that
//! joins everything before handing back the system's final report.

use std::io;
use std::net::{SocketAddr, TcpListener};
#[cfg(unix)]
use std::os::unix::net::UnixListener;
#[cfg(unix)]
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{NetCounters, PimFabric, PimSystem, QosClass, SystemReport};

use super::codec::WireStats;
use super::conn::{handle_conn, snapshot, Session};

/// Tunables of the network front end. `cols` is the row width in bits of
/// the serving system's DRAM geometry — handed to clients in `Welcome`
/// so they can size their `WriteRow` payloads without guessing.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Row width in bits (`DramConfig::geometry.cols_per_row`).
    pub cols: usize,
    /// Per-connection cap on unresolved tickets; beyond it requests get
    /// an immediate `Busy` reply and are NOT enqueued. Latency and
    /// Throughput sessions get the full cap; Background sessions are
    /// admitted against [`Self::class_cap`]'s reduced quota, so overload
    /// sheds background work first.
    pub max_inflight: usize,
    /// A connection silent this long (with nothing in flight) is reaped.
    pub idle_timeout: Duration,
    /// Socket write timeout; a stalled peer trips it and the connection
    /// tears down instead of wedging the writer thread.
    pub write_timeout: Duration,
    /// Reader/writer poll tick: how often a blocked socket read or an
    /// empty reply queue re-checks stop/idle/teardown conditions.
    pub tick: Duration,
    /// How often an accept loop re-checks the stop flag when idle.
    pub accept_tick: Duration,
    /// Session class for connections whose `Hello` names none.
    pub default_qos: QosClass,
}

impl NetConfig {
    pub fn new(cols: usize) -> Self {
        NetConfig {
            cols,
            max_inflight: 64,
            idle_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(5),
            tick: Duration::from_millis(25),
            accept_tick: Duration::from_millis(10),
            default_qos: QosClass::default(),
        }
    }

    /// The admission quota a session of `class` runs under: full cap for
    /// Latency/Throughput, a quarter (at least one) for Background.
    pub fn class_cap(&self, class: QosClass) -> usize {
        match class {
            QosClass::Latency | QosClass::Throughput => self.max_inflight,
            QosClass::Background => (self.max_inflight / 4).max(1),
        }
    }
}

/// What the server fronts: a standalone system or a sharded fabric.
#[derive(Clone)]
enum Backend {
    System(PimSystem),
    Fabric(PimFabric),
}

impl Backend {
    fn open_session(&self) -> Session {
        match self {
            Backend::System(s) => Session::Sys(s.client()),
            Backend::Fabric(f) => Session::Fab(f.client()),
        }
    }

    fn shutdown(&self) -> SystemReport {
        match self {
            Backend::System(s) => s.shutdown(),
            Backend::Fabric(f) => f.shutdown(),
        }
    }
}

/// The network server: owns the serving system, listens on any number of
/// TCP/UDS endpoints, and maps every accepted connection onto its own
/// [`PimClient`] session (see [`super::conn`]).
///
/// [`PimClient`]: crate::coordinator::PimClient
pub struct NetServer {
    backend: Backend,
    cfg: NetConfig,
    counters: Arc<NetCounters>,
    stop: Arc<AtomicBool>,
    accept_threads: Mutex<Vec<JoinHandle<()>>>,
    conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    #[cfg(unix)]
    uds_paths: Mutex<Vec<PathBuf>>,
}

impl NetServer {
    /// Front a standalone single-channel system.
    pub fn new(system: PimSystem, cfg: NetConfig) -> Self {
        Self::with_backend(Backend::System(system), cfg)
    }

    /// Front a sharded multi-channel fabric: connections place their
    /// sessions shard-first, exactly like in-process fabric clients.
    pub fn over_fabric(fabric: PimFabric, cfg: NetConfig) -> Self {
        Self::with_backend(Backend::Fabric(fabric), cfg)
    }

    fn with_backend(backend: Backend, cfg: NetConfig) -> Self {
        NetServer {
            backend,
            cfg,
            counters: Arc::new(NetCounters::default()),
            stop: Arc::new(AtomicBool::new(false)),
            accept_threads: Mutex::new(Vec::new()),
            conn_threads: Arc::new(Mutex::new(Vec::new())),
            #[cfg(unix)]
            uds_paths: Mutex::new(Vec::new()),
        }
    }

    /// The server's counters (shared with every connection thread).
    pub fn counters(&self) -> &NetCounters {
        &self.counters
    }

    /// Snapshot the counters in wire form.
    pub fn stats(&self) -> WireStats {
        snapshot(&self.counters)
    }

    /// Start a TCP accept loop. Returns the bound address, so `:0`
    /// requests (ephemeral port) report where they actually landed.
    pub fn listen_tcp(&self, addr: &str) -> io::Result<SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let backend = self.backend.clone();
        let cfg = self.cfg.clone();
        let counters = self.counters.clone();
        let stop = self.stop.clone();
        let conns = self.conn_threads.clone();
        let handle = std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = stream.set_nonblocking(false);
                        let session = backend.open_session();
                        let cfg = cfg.clone();
                        let counters = counters.clone();
                        let stop = stop.clone();
                        let t = std::thread::spawn(move || {
                            handle_conn(stream, session, cfg, counters, stop);
                        });
                        conns.lock().unwrap().push(t);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(cfg.accept_tick);
                    }
                    Err(_) => break,
                }
            }
        });
        self.accept_threads.lock().unwrap().push(handle);
        Ok(local)
    }

    /// Start a Unix-domain accept loop on `path` (an existing socket
    /// file there is replaced; the file is unlinked again at shutdown).
    #[cfg(unix)]
    pub fn listen_uds(&self, path: &Path) -> io::Result<()> {
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)?;
        listener.set_nonblocking(true)?;
        self.uds_paths.lock().unwrap().push(path.to_path_buf());
        let backend = self.backend.clone();
        let cfg = self.cfg.clone();
        let counters = self.counters.clone();
        let stop = self.stop.clone();
        let conns = self.conn_threads.clone();
        let handle = std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = stream.set_nonblocking(false);
                        let session = backend.open_session();
                        let cfg = cfg.clone();
                        let counters = counters.clone();
                        let stop = stop.clone();
                        let t = std::thread::spawn(move || {
                            handle_conn(stream, session, cfg, counters, stop);
                        });
                        conns.lock().unwrap().push(t);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(cfg.accept_tick);
                    }
                    Err(_) => break,
                }
            }
        });
        self.accept_threads.lock().unwrap().push(handle);
        Ok(())
    }

    /// Stop accepting, join every accept and connection thread (live
    /// connections finish their teardown — rows freed, seats released),
    /// then shut the system down and return its final report.
    pub fn shutdown(self) -> SystemReport {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.accept_threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
        for t in self.conn_threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
        #[cfg(unix)]
        for p in self.uds_paths.lock().unwrap().drain(..) {
            let _ = std::fs::remove_file(&p);
        }
        self.backend.shutdown()
    }
}
