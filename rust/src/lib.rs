//! # shiftdram — migration-cell in-DRAM bit-shifting
//!
//! Reproduction of **"Shifting in-DRAM"** (Tegge & Jones, CS.AR 2026): a
//! DRAM subarray design that performs bidirectional full-row bit-shifting on
//! horizontally-stored data using *migration cells* (two-port 1T1C cells
//! straddling adjacent bitlines) placed as one row at the top and one at the
//! bottom of every subarray. A 1-bit full-row shift is a sequence of 4 AAP
//! (ACT-ACT-PRE) commands.
//!
//! The crate is the L3 coordinator of a three-layer stack:
//!
//! * [`dram`] — the DRAM substrate: open-bitline subarrays, JEDEC DDR3
//!   timing state machine, IDD-based energy model, refresh.
//! * [`pim`] — bit-accurate PIM primitives: RowClone/AAP, Ambit DRA/TRA
//!   (MAJ/AND/OR), dual-contact-cell NOT, and the paper's migration-cell
//!   shift, plus a program builder.
//! * [`pim::compile`] — the compile-once/execute-anywhere layer:
//!   position-relative `CompiledProgram`s with precomputed latency/energy/
//!   census footprints, shared via an `Arc`-held LRU `ProgramCache` and
//!   retargeted to any (bank, subarray, row) in O(1) — the SIMDRAM-style
//!   μProgram split between compilation and the thin replay controller.
//! * [`sim`] — the command-level engine that executes PIM programs against
//!   the timing + energy model (the NVMain substitute; Tables 2–3), with a
//!   `run_compiled` fast path that advances per compiled block and stays
//!   bit-identical to per-command simulation.
//! * [`circuit`] — the LTSPICE substitute: technology-node parameters
//!   (Table 1), a native transient oracle, and the Monte-Carlo harness that
//!   drives the AOT-compiled JAX/Pallas kernel through PJRT (Table 4).
//! * [`layout`] — the Virtuoso substitute: 22 nm geometry, MIM-cap sizing,
//!   DRC-style checks, and area-overhead accounting (Table 5, Fig. 4).
//! * [`baselines`] — SIMDRAM / DRISA / Ambit / CPU-data-movement cost
//!   models (§5.1.5, §5.1.6).
//! * [`coordinator`] — the handle-based serving layer (§5.1.4): client
//!   sessions allocate opaque, system-placed row handles, submit whole
//!   kernels, and receive typed tickets that resolve to
//!   `Result<T, PimError>`; underneath, a bank-parallel router (with
//!   per-bank row slabs and cost-weighted load), per-bank batchers, and
//!   one worker per bank replay compiled programs kernel-at-a-time.
//!   Above that, the sharded multi-channel fabric
//!   ([`coordinator::fabric`]) runs one such coordinator per channel —
//!   private caches, slabs, and metrics per shard — with two-level
//!   placement and cost-weighted work stealing of unplaced jobs.
//! * [`net`] — the network serving front end: a hand-rolled framed
//!   binary protocol over TCP/Unix-domain sockets mapping each
//!   connection onto a coordinator session, with out-of-order reply
//!   streaming (correlation ids + non-blocking tickets), `Busy`
//!   backpressure, idle reaping, leak-free disconnect teardown, and an
//!   open-loop tail-latency load generator (`BENCH_serve.json`).
//! * [`apps`] — application kernels compiled to PIM programs: adders,
//!   shift-and-add multiplication, GF(2⁸), AES steps, Reed-Solomon —
//!   each a thin client of the same serving API (`apps::ElementCtx`).
//! * [`runtime`] — the PJRT bridge that loads and executes
//!   `artifacts/*.hlo.txt`; Python never runs on the request path. In the
//!   offline build it is an API-compatible stub and every caller falls
//!   back to the native oracle (see the module docs).

pub mod apps;
pub mod baselines;
pub mod circuit;
pub mod config;
pub mod coordinator;
pub mod dram;
pub mod layout;
pub mod net;
pub mod pim;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
