//! Reed-Solomon encoding in DRAM (§8.0.2): systematic RS(n, k) over
//! GF(2⁸), encoding thousands of codewords in parallel.
//!
//! Layout: structure-of-arrays, like the AES kernel — row `i` packs symbol
//! `i` of many codewords. The classic LFSR encoder then runs entirely on
//! rows: per message symbol, one row XOR computes the feedback and each
//! parity row updates with a GF constant multiply (xtime chains = the
//! paper's shifts) and an XOR.
//!
//! The full (k, n_parity) LFSR schedule and each syndrome pass are cached
//! kernels — an encoder instance compiles once, then batch after batch
//! replays the same compiled program.
//!
//! Row map: message rows `MSG_BASE..MSG_BASE+k`, parity rows
//! `PAR_BASE..PAR_BASE+(n−k)`, feedback row, plus the GF scratch/masks
//! installed by `gf::install_gf_masks` (rows 8–30).

use crate::apps::elements::{ElementCtx, PimTape};
use crate::apps::gf::{build_gf_mul_const, gf_mul_ref, install_gf_masks};
use crate::pim::PimOp;

pub const MSG_BASE: usize = 40;
pub const PAR_BASE: usize = 72;
pub const T_FB: usize = 88;
pub const T_MUL: usize = 89;

/// Compute the RS generator polynomial g(x) = Π (x − α^i), α = 0x02,
/// for `n_parity` roots. Returns coefficients g[0..n_parity] (monic
/// leading coefficient implied).
pub fn generator_poly(n_parity: usize) -> Vec<u8> {
    let mut g = vec![1u8];
    let mut alpha_i = 1u8; // roots α^0, α^1, … (QR/most-common convention)
    for _ in 0..n_parity {
        // multiply g(x) by (x + α^i)
        let mut next = vec![0u8; g.len() + 1];
        for (j, &c) in g.iter().enumerate() {
            next[j] ^= gf_mul_ref(c, alpha_i);
            next[j + 1] ^= c;
        }
        g = next;
        alpha_i = gf_mul_ref(alpha_i, 2);
    }
    g.pop(); // drop the monic leading 1
    g
}

/// Host reference: systematic RS encode of one message.
pub fn rs_encode_ref(msg: &[u8], n_parity: usize) -> Vec<u8> {
    let g = generator_poly(n_parity);
    let mut parity = vec![0u8; n_parity];
    for &m in msg {
        let fb = m ^ parity[n_parity - 1];
        for j in (1..n_parity).rev() {
            parity[j] = parity[j - 1] ^ gf_mul_ref(fb, g[j]);
        }
        parity[0] = gf_mul_ref(fb, g[0]);
    }
    parity
}

/// In-DRAM batch encoder.
pub struct RsEncoder {
    pub k: usize,
    pub n_parity: usize,
    g: Vec<u8>,
}

impl RsEncoder {
    pub fn new(k: usize, n_parity: usize) -> Self {
        assert!(k + n_parity <= 255, "RS over GF(2^8)");
        assert!(n_parity >= 1 && PAR_BASE + n_parity <= 88 && MSG_BASE + k <= 72);
        RsEncoder { k, n_parity, g: generator_poly(n_parity) }
    }

    /// One-time context setup (GF masks).
    pub fn install(&self, ctx: &mut ElementCtx) {
        install_gf_masks(ctx);
    }

    /// Load message symbol rows: `msgs[j]` is codeword j's k symbols.
    pub fn load_messages(&self, ctx: &mut ElementCtx, msgs: &[Vec<u8>]) {
        assert_eq!(msgs.len(), ctx.n_elements());
        for i in 0..self.k {
            let vals: Vec<u64> = msgs.iter().map(|m| m[i] as u64).collect();
            ctx.set_row(MSG_BASE + i, ctx.pack(&vals));
        }
    }

    /// Run the LFSR encoder over all codewords in parallel. The whole
    /// (k, n_parity) schedule is one cached kernel.
    pub fn encode(&self, ctx: &mut ElementCtx) {
        ctx.run_kernel(
            "rs.encode",
            &[self.k as u64, self.n_parity as u64],
            |t| self.build_encode(t),
        );
    }

    /// Emit the LFSR encode schedule onto a tape (public like the other
    /// app builders, so it composes and benches can record it directly).
    pub fn build_encode(&self, tape: &mut impl PimTape) {
        let np = self.n_parity;
        // feedback/product rows are dead once the parity rows are final
        // (the syndrome pass does NOT declare T_MUL — hosts read it back)
        tape.scratch(T_FB);
        tape.scratch(T_MUL);
        for j in 0..np {
            tape.op(PimOp::SetZero { dst: PAR_BASE + j });
        }
        for i in 0..self.k {
            // feedback = msg[i] ^ parity[np-1]
            tape.op(PimOp::Xor { a: MSG_BASE + i, b: PAR_BASE + np - 1, dst: T_FB });
            for j in (1..np).rev() {
                build_gf_mul_const(tape, T_FB, T_MUL, self.g[j].max(1));
                if self.g[j] == 0 {
                    tape.op(PimOp::Copy { src: PAR_BASE + j - 1, dst: PAR_BASE + j });
                } else {
                    tape.op(PimOp::Xor {
                        a: PAR_BASE + j - 1,
                        b: T_MUL,
                        dst: PAR_BASE + j,
                    });
                }
            }
            build_gf_mul_const(tape, T_FB, PAR_BASE, self.g[0]);
        }
    }

    /// Emit one Horner syndrome pass (root α^i = `alpha_i`) onto a tape.
    fn build_syndrome_pass(&self, tape: &mut impl PimTape, alpha_i: u8) {
        let np = self.n_parity;
        // Horner over symbol rows, highest degree first: message rows
        // are the high coefficients, parity rows the low ones.
        tape.op(PimOp::SetZero { dst: T_MUL });
        for i in 0..self.k {
            if alpha_i != 1 {
                build_gf_mul_const(tape, T_MUL, T_MUL, alpha_i);
            }
            tape.op(PimOp::Xor { a: T_MUL, b: MSG_BASE + i, dst: T_MUL });
        }
        for j in (0..np).rev() {
            if alpha_i != 1 {
                build_gf_mul_const(tape, T_MUL, T_MUL, alpha_i);
            }
            tape.op(PimOp::Xor { a: T_MUL, b: PAR_BASE + j, dst: T_MUL });
        }
    }

    /// In-DRAM syndrome check: after encoding, evaluate the full codeword
    /// c(x) = msg·x^np + parity at each generator root α^i via Horner's
    /// rule — all row ops (gf_mul_const by α^i + XOR). A zero syndrome row
    /// for every root certifies the codeword; any nonzero byte flags the
    /// corresponding codeword as corrupted. Returns, per codeword, whether
    /// all syndromes are zero. Each root's pass is a cached kernel; only
    /// the host-side readback between passes stays data-dependent.
    pub fn syndromes_ok(&self, ctx: &mut ElementCtx) -> Vec<bool> {
        let np = self.n_parity;
        let n = ctx.n_elements();
        let mut ok = vec![true; n];
        let mut alpha_i = 1u8;
        for _ in 0..np {
            ctx.run_kernel(
                "rs.syndrome_pass",
                &[self.k as u64, self.n_parity as u64, alpha_i as u64],
                |t| self.build_syndrome_pass(t, alpha_i),
            );
            let syn = ctx.unpack(&ctx.row(T_MUL));
            for (c, &s) in syn.iter().enumerate() {
                ok[c] &= s == 0;
            }
            alpha_i = gf_mul_ref(alpha_i, 2);
        }
        ok
    }

    /// Read back parity rows: per codeword, `n_parity` symbols.
    pub fn read_parity(&self, ctx: &ElementCtx) -> Vec<Vec<u8>> {
        let n = ctx.n_elements();
        let mut out = vec![vec![0u8; self.n_parity]; n];
        for j in 0..self.n_parity {
            let vals = ctx.unpack(&ctx.row(PAR_BASE + j));
            for (c, &v) in vals.iter().enumerate() {
                out[c][j] = v as u8;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn generator_poly_rs_4_parity() {
        // well-known RS generator over GF(2^8), α=2, 4 parity symbols:
        // g(x) = x^4 + 0x0f x^3 + 0x36 x^2 + 0x78 x + 0x40
        let g = generator_poly(4);
        assert_eq!(g, vec![0x40, 0x78, 0x36, 0x0F]);
    }

    #[test]
    fn ref_encoder_properties() {
        // parity of the zero message is zero
        assert_eq!(rs_encode_ref(&[0; 10], 4), vec![0; 4]);
        // linearity: parity(a ^ b) = parity(a) ^ parity(b)
        let a = [1u8, 2, 3, 4, 5];
        let b = [9u8, 8, 7, 6, 5];
        let ab: Vec<u8> = a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
        let pa = rs_encode_ref(&a, 4);
        let pb = rs_encode_ref(&b, 4);
        let pab = rs_encode_ref(&ab, 4);
        for j in 0..4 {
            assert_eq!(pab[j], pa[j] ^ pb[j]);
        }
    }

    #[test]
    fn in_dram_matches_reference() {
        let enc = RsEncoder::new(11, 4); // RS(15,11)-style
        let mut ctx = ElementCtx::new(96, 128, 8);
        enc.install(&mut ctx);
        let mut rng = Rng::new(21);
        let n = ctx.n_elements();
        let msgs: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..11).map(|_| rng.below(256) as u8).collect())
            .collect();
        enc.load_messages(&mut ctx, &msgs);
        enc.encode(&mut ctx);
        let got = enc.read_parity(&ctx);
        for (j, m) in msgs.iter().enumerate() {
            assert_eq!(got[j], rs_encode_ref(m, 4), "codeword {j}");
        }
    }

    #[test]
    fn syndromes_certify_and_flag() {
        let enc = RsEncoder::new(9, 4);
        let mut ctx = ElementCtx::new(96, 128, 8);
        enc.install(&mut ctx);
        let mut rng = Rng::new(61);
        let n = ctx.n_elements();
        let msgs: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..9).map(|_| rng.below(256) as u8).collect())
            .collect();
        enc.load_messages(&mut ctx, &msgs);
        enc.encode(&mut ctx);
        // clean codewords: every syndrome must be zero
        let ok = enc.syndromes_ok(&mut ctx);
        assert!(ok.iter().all(|&b| b), "clean codewords must certify");
        // corrupt one message symbol of codeword 5 (after encoding):
        // its syndromes must flag, the others stay clean
        let mut vals = ctx.unpack(&ctx.row(MSG_BASE + 2));
        vals[5] ^= 0x21;
        let packed = ctx.pack(&vals);
        ctx.set_row(MSG_BASE + 2, packed);
        let ok = enc.syndromes_ok(&mut ctx);
        assert!(!ok[5], "corrupted codeword must be flagged");
        assert!(ok.iter().enumerate().all(|(j, &b)| b || j == 5));
    }

    #[test]
    fn corrupted_symbol_changes_parity() {
        // failure-injection sanity: RS parity must detect a flipped symbol
        let enc = RsEncoder::new(5, 2);
        let mut ctx = ElementCtx::new(96, 128, 8);
        enc.install(&mut ctx);
        let n = ctx.n_elements();
        let msgs: Vec<Vec<u8>> = (0..n).map(|_| vec![7, 7, 7, 7, 7]).collect();
        enc.load_messages(&mut ctx, &msgs);
        enc.encode(&mut ctx);
        let clean = enc.read_parity(&ctx);

        let mut bad = msgs.clone();
        bad[0][2] ^= 0x10;
        enc.load_messages(&mut ctx, &bad);
        enc.encode(&mut ctx);
        let dirty = enc.read_parity(&ctx);
        assert_ne!(clean[0], dirty[0]);
        assert_eq!(clean[1], dirty[1], "other codewords unaffected");
    }
}
