//! Shift-and-add multiplication (§1's motivating application): W-bit × W-bit
//! → W-bit (mod 2^W) over packed elements, entirely in-DRAM.
//!
//! Classic algorithm: for each bit k of the multiplier, add
//! `(multiplicand << k)` into the accumulator where that bit is set. The
//! per-element multiplier bit is broadcast to all W positions with a
//! log-doubling shift-OR tree — every step is migration-cell shifts plus
//! Ambit logic.
//!
//! The whole multiply is one cached kernel: [`shift_and_add_mul`] records
//! the W-round schedule (inlining the Kogge-Stone adder builder) once per
//! shape, then replays it from the program cache — thousands of macro-ops
//! fetched with one lookup.
//!
//! Row map: 0,1 operands; 2 product; 3..7 adder temps; 8..33 masks;
//! 34..39 multiplier temps.

use crate::apps::adder::{build_kogge_stone_add, mask_row_for_dir};
use crate::apps::elements::{shift_in_element, Dir, ElementCtx, PimTape};
use crate::pim::PimOp;

const T_ACC: usize = 34;
const T_SHA: usize = 35;
const T_B: usize = 36;
const T_BIT: usize = 37;
const T_BCAST: usize = 38;
const T_PARTIAL: usize = 39;
/// LSB mask (installed here; distinct from GF's copy)
const M_LSB: usize = 40;

/// One-time mask setup (call after `adder::install_masks`).
pub fn install_mul_masks(ctx: &mut ElementCtx) {
    ctx.set_row(M_LSB, ctx.bit_mask(&[0]));
}

/// Broadcast each element's bit-0 flag to all W positions:
/// `t |= t << 1; t |= t << 2; ...` (log₂W rounds).
fn broadcast_lsb(tape: &mut impl PimTape, row: usize) {
    let mut d = 1;
    while d < tape.width() {
        shift_in_element(tape, row, T_BCAST, Dir::Up, d, mask_row_for_dir(Dir::Up, d));
        tape.op(PimOp::Or { a: row, b: T_BCAST, dst: row });
        d *= 2;
    }
}

/// `row_out := row_a * row_b (mod 2^W)` per element. Cached per shape.
pub fn shift_and_add_mul(ctx: &mut ElementCtx, row_a: usize, row_b: usize, row_out: usize) {
    ctx.run_kernel(
        "multiplier.shift_and_add",
        &[row_a as u64, row_b as u64, row_out as u64],
        |t| build_shift_and_add_mul(t, row_a, row_b, row_out),
    );
}

/// Emit the shift-and-add schedule onto a tape.
pub fn build_shift_and_add_mul(
    tape: &mut impl PimTape,
    row_a: usize,
    row_b: usize,
    row_out: usize,
) {
    let w = tape.width();
    // the multiplier temps and the inlined adder's temps (3..=7) are all
    // dead after the kernel — declared so the opt-level-2 passes can
    // merge their live ranges
    for t in [T_ACC, T_SHA, T_B, T_BIT, T_BCAST, T_PARTIAL] {
        tape.scratch(t);
    }
    tape.op(PimOp::SetZero { dst: T_ACC });
    tape.op(PimOp::Copy { src: row_a, dst: T_SHA });
    tape.op(PimOp::Copy { src: row_b, dst: T_B });
    for k in 0..w {
        // bit k of b, as a full-element condition mask
        tape.op(PimOp::And { a: T_B, b: M_LSB, dst: T_BIT });
        broadcast_lsb(tape, T_BIT);
        // partial = (a << k) & cond ; acc += partial
        tape.op(PimOp::And { a: T_SHA, b: T_BIT, dst: T_PARTIAL });
        build_kogge_stone_add(tape, T_ACC, T_PARTIAL, T_ACC);
        if k + 1 < w {
            shift_in_element(tape, T_SHA, T_SHA, Dir::Up, 1, mask_row_for_dir(Dir::Up, 1));
            shift_in_element(tape, T_B, T_B, Dir::Down, 1, mask_row_for_dir(Dir::Down, 1));
        }
    }
    tape.op(PimOp::Copy { src: T_ACC, dst: row_out });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::adder::install_masks;
    use crate::util::Rng;

    fn setup(width: usize) -> ElementCtx {
        let mut ctx = ElementCtx::new(48, 256, width);
        install_masks(&mut ctx);
        install_mul_masks(&mut ctx);
        ctx
    }

    #[test]
    fn mul_8bit_random() {
        let mut ctx = setup(8);
        let mut rng = Rng::new(1);
        let n = ctx.n_elements();
        let a: Vec<u64> = (0..n).map(|_| rng.below(256) as u64).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.below(256) as u64).collect();
        ctx.set_row(0, ctx.pack(&a));
        ctx.set_row(1, ctx.pack(&b));
        shift_and_add_mul(&mut ctx, 0, 1, 2);
        let got = ctx.unpack(&ctx.row(2));
        let want: Vec<u64> = a.iter().zip(&b).map(|(x, y)| (x * y) & 0xFF).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn mul_identities() {
        let mut ctx = setup(8);
        let n = ctx.n_elements();
        let a: Vec<u64> = (0..n).map(|j| (j as u64 * 7 + 1) % 256).collect();
        // ×1 = identity
        ctx.set_row(0, ctx.pack(&a));
        ctx.set_row(1, ctx.pack(&vec![1; n]));
        shift_and_add_mul(&mut ctx, 0, 1, 2);
        assert_eq!(ctx.unpack(&ctx.row(2)), a);
        // ×0 = zero
        ctx.set_row(1, ctx.pack(&vec![0; n]));
        shift_and_add_mul(&mut ctx, 0, 1, 2);
        assert_eq!(ctx.unpack(&ctx.row(2)), vec![0; n]);
        // ×2 = shift
        ctx.set_row(1, ctx.pack(&vec![2; n]));
        shift_and_add_mul(&mut ctx, 0, 1, 2);
        let want: Vec<u64> = a.iter().map(|x| (x << 1) & 0xFF).collect();
        assert_eq!(ctx.unpack(&ctx.row(2)), want);
    }

    #[test]
    fn mul_16bit() {
        let mut ctx = setup(16);
        let mut rng = Rng::new(9);
        let n = ctx.n_elements();
        let a: Vec<u64> = (0..n).map(|_| rng.below(65536) as u64).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.below(65536) as u64).collect();
        ctx.set_row(0, ctx.pack(&a));
        ctx.set_row(1, ctx.pack(&b));
        shift_and_add_mul(&mut ctx, 0, 1, 2);
        let got = ctx.unpack(&ctx.row(2));
        let want: Vec<u64> = a.iter().zip(&b).map(|(x, y)| (x * y) & 0xFFFF).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn aap_budget_scales_with_width() {
        let mut c8 = setup(8);
        c8.set_row(0, c8.pack(&vec![3; c8.n_elements()]));
        c8.set_row(1, c8.pack(&vec![5; c8.n_elements()]));
        shift_and_add_mul(&mut c8, 0, 1, 2);
        assert!(c8.aaps > 100, "real programs cost hundreds of AAPs: {}", c8.aaps);
    }
}
