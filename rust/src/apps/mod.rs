//! Application kernels compiled to PIM programs — the workloads the
//! paper's introduction motivates, each verified bit-exactly against host
//! arithmetic:
//!
//! * [`adder`] — ripple-carry and Kogge-Stone adders (§8.0.1)
//! * [`multiplier`] — shift-and-add multiplication (§1)
//! * [`gf`] — GF(2⁸) arithmetic: xtime, constant and full multiplies (§1)
//! * [`aes`] — AES MixColumns / AddRoundKey / ShiftRows (§8.0.2)
//! * [`reed_solomon`] — batch systematic RS encoding (§8.0.2)
//!
//! All of them are element-parallel over a packed horizontal row (see
//! [`elements`]) — no transposition anywhere, which is the paper's point.
//!
//! Kernels are **compiled once**: every entry point records its macro-op
//! schedule through the [`PimTape`] trait at most once per (kernel shape,
//! DRAM config), stores the resulting `pim::compile::CompiledProgram` in
//! the shared program cache, and replays it from there on every later
//! call (see [`elements::ElementCtx::run_kernel`]).

pub mod adder;
pub mod aes;
pub mod elements;
pub mod gf;
pub mod multiplier;
pub mod reed_solomon;

pub use elements::{shift_in_element, Dir, ElementCtx, PimTape, ProgramSketch};
