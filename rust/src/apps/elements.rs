//! Element-wise PIM programming helpers.
//!
//! Application data is packed horizontally: a row of `cols` bits holds
//! `cols / W` little-endian W-bit elements (bit `i` of element `j` at
//! column `W*j + i` — the conventional horizontal layout the paper's
//! design operates on, no transposition).
//!
//! Because the migration-cell shift moves the *whole row*, element-local
//! shifts are built as `row shift` + `boundary mask`: bits that crossed an
//! element boundary are cleared with a precomputed constant mask row.
//! Mask rows are host-written constants (like Ambit's control rows, they
//! are initialized once at boot).
//!
//! NOTE on direction names: a column-space `ShiftDir::Right` moves bit `i`
//! to bit `i+1`, i.e. it is the *arithmetic left shift* (×2) of the packed
//! little-endian elements. [`Dir::Up`] / [`Dir::Down`] name the arithmetic
//! directions to keep callers sane.

use crate::dram::subarray::Subarray;
use crate::pim::{executor, PimOp};
use crate::util::{BitRow, ShiftDir};

/// Arithmetic shift direction within elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// toward the MSB (×2 per step) — column-space Right
    Up,
    /// toward the LSB (÷2 per step) — column-space Left
    Down,
}

impl Dir {
    pub fn col(self) -> ShiftDir {
        match self {
            Dir::Up => ShiftDir::Right,
            Dir::Down => ShiftDir::Left,
        }
    }
}

/// A subarray "tape" for element-wise programs: tracks the subarray, the
/// element width, and the command census of everything executed.
pub struct ElementCtx {
    pub sa: Subarray,
    pub width: usize,
    pub aaps: usize,
    pub tras: usize,
    pub dras: usize,
}

impl ElementCtx {
    pub fn new(rows: usize, cols: usize, width: usize) -> Self {
        assert!(cols % width == 0, "row must pack whole elements");
        ElementCtx { sa: Subarray::new(rows, cols), width, aaps: 0, tras: 0, dras: 0 }
    }

    pub fn cols(&self) -> usize {
        self.sa.cols()
    }

    pub fn n_elements(&self) -> usize {
        self.cols() / self.width
    }

    /// Execute one macro-op, accounting commands.
    pub fn op(&mut self, op: PimOp) {
        let cmds = op.lower();
        for c in &cmds {
            match c {
                crate::dram::address::Command::Aap { .. } => self.aaps += 1,
                crate::dram::address::Command::Tra { .. } => self.tras += 1,
                crate::dram::address::Command::Dra { .. } => self.dras += 1,
                _ => {}
            }
        }
        executor::run(&mut self.sa, &cmds);
    }

    /// Host-write a constant/mask row.
    pub fn set_row(&mut self, row: usize, bits: BitRow) {
        self.sa.write_row(row, bits);
    }

    pub fn row(&self, row: usize) -> &BitRow {
        self.sa.read_row(row)
    }

    /// Pack u64 element values into a row image.
    pub fn pack(&self, values: &[u64]) -> BitRow {
        assert_eq!(values.len(), self.n_elements());
        let mut r = BitRow::zeros(self.cols());
        for (j, &v) in values.iter().enumerate() {
            assert!(self.width == 64 || v < (1u64 << self.width), "value too wide");
            for i in 0..self.width {
                if (v >> i) & 1 == 1 {
                    r.set(self.width * j + i, true);
                }
            }
        }
        r
    }

    /// Unpack a row image into element values.
    pub fn unpack(&self, r: &BitRow) -> Vec<u64> {
        (0..self.n_elements())
            .map(|j| {
                let mut v = 0u64;
                for i in 0..self.width {
                    if r.get(self.width * j + i) {
                        v |= 1 << i;
                    }
                }
                v
            })
            .collect()
    }

    /// Mask row with 1s at columns where `col % width ∈ bits`.
    pub fn bit_mask(&self, bits: &[usize]) -> BitRow {
        let mut r = BitRow::zeros(self.cols());
        for col in 0..self.cols() {
            if bits.contains(&(col % self.width)) {
                r.set(col, true);
            }
        }
        r
    }

    /// Mask that keeps bits which did NOT cross an element boundary after
    /// an arithmetic shift by `d` in direction `dir`:
    /// Up: keep `col % width >= d`; Down: keep `col % width < width − d`.
    pub fn boundary_mask(&self, dir: Dir, d: usize) -> BitRow {
        let mut r = BitRow::zeros(self.cols());
        for col in 0..self.cols() {
            let i = col % self.width;
            let keep = match dir {
                Dir::Up => i >= d,
                Dir::Down => i < self.width - d,
            };
            if keep {
                r.set(col, true);
            }
        }
        r
    }
}

/// Element-local shift: `dst := (src shifted by d within each element)`.
/// Issues `4·d` AAPs for the row shifts plus one AND against the boundary
/// mask in `mask_row` (which the caller must have initialized with
/// [`ElementCtx::boundary_mask`] for this (dir, d)).
pub fn shift_in_element(
    ctx: &mut ElementCtx,
    src: usize,
    dst: usize,
    dir: Dir,
    d: usize,
    mask_row: usize,
) {
    assert!(d < ctx.width);
    if d == 0 {
        ctx.op(PimOp::Copy { src, dst });
        return;
    }
    ctx.op(PimOp::ShiftBy { src, dst, n: d, dir: dir.col() });
    ctx.op(PimOp::And { a: dst, b: mask_row, dst });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ctx() -> ElementCtx {
        ElementCtx::new(24, 256, 8)
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let c = ctx();
        let mut rng = Rng::new(1);
        let vals: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        let row = c.pack(&vals);
        assert_eq!(c.unpack(&row), vals);
    }

    #[test]
    fn boundary_masks() {
        let c = ctx();
        let up2 = c.boundary_mask(Dir::Up, 2);
        assert!(!up2.get(0) && !up2.get(1) && up2.get(2) && up2.get(7));
        assert!(!up2.get(8) && up2.get(10));
        let down3 = c.boundary_mask(Dir::Down, 3);
        assert!(down3.get(0) && down3.get(4) && !down3.get(5) && !down3.get(7));
    }

    #[test]
    fn element_shift_up_is_mul2() {
        let mut c = ctx();
        let mut rng = Rng::new(2);
        let vals: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        let row = c.pack(&vals);
        c.set_row(0, row);
        let m = c.boundary_mask(Dir::Up, 1);
        c.set_row(10, m);
        shift_in_element(&mut c, 0, 1, Dir::Up, 1, 10);
        let got = c.unpack(c.row(1));
        let want: Vec<u64> = vals.iter().map(|v| (v << 1) & 0xFF).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn element_shift_down() {
        let mut c = ctx();
        let vals: Vec<u64> = (0..32).map(|j| (j * 37 + 5) as u64 % 256).collect();
        let row = c.pack(&vals);
        c.set_row(0, row);
        let m = c.boundary_mask(Dir::Down, 3);
        c.set_row(10, m);
        shift_in_element(&mut c, 0, 1, Dir::Down, 3, 10);
        let got = c.unpack(c.row(1));
        let want: Vec<u64> = vals.iter().map(|v| v >> 3).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn aap_accounting() {
        let mut c = ctx();
        c.set_row(10, c.boundary_mask(Dir::Up, 1));
        let before = c.aaps;
        shift_in_element(&mut c, 0, 1, Dir::Up, 1, 10);
        // 4 AAPs for the shift + 5 for the AND (4 AAP + TRA)
        assert_eq!(c.aaps - before, 8);
        assert_eq!(c.tras, 1);
    }
}
