//! Element-wise PIM programming helpers.
//!
//! Application data is packed horizontally: a row of `cols` bits holds
//! `cols / W` little-endian W-bit elements (bit `i` of element `j` at
//! column `W*j + i` — the conventional horizontal layout the paper's
//! design operates on, no transposition).
//!
//! Because the migration-cell shift moves the *whole row*, element-local
//! shifts are built as `row shift` + `boundary mask`: bits that crossed an
//! element boundary are cleared with a precomputed constant mask row.
//! Mask rows are host-written constants (like Ambit's control rows, they
//! are initialized once at boot).
//!
//! # One execution path, two entry points
//!
//! Kernel bodies are written against the [`PimTape`] trait — a sink of
//! macro-ops plus the element width — and [`ElementCtx`] is a **thin
//! client of the serving system**: it wraps a private single-bank
//! [`crate::coordinator::PimSystem`] plus a [`PimClient`] session whose
//! [`RowHandle`]s back the context's row indices. Both entry points go through the same
//! client path external callers use — there is no second lowering or
//! replay implementation in the app layer:
//!
//! * [`ElementCtx::run_kernel`] records the body once into a named
//!   [`Kernel`] and submits it whole: one wire request, one program-cache
//!   fetch, one `run_compiled` replay, regardless of how many macro-ops
//!   the body emitted.
//! * [`ElementCtx::op`] (the [`PimTape`] impl) submits each macro-op as a
//!   single-op kernel — the incremental tape used for data-dependent
//!   fragments and as the reference the whole-kernel path is
//!   property-tested against.
//!
//! NOTE on direction names: a column-space `ShiftDir::Right` moves bit `i`
//! to bit `i+1`, i.e. it is the *arithmetic left shift* (×2) of the packed
//! little-endian elements. [`Dir::Up`] / [`Dir::Down`] name the arithmetic
//! directions to keep callers sane.

use std::sync::Arc;

use crate::config::DramConfig;
use crate::coordinator::{Kernel, PimClient, RowHandle, SystemBuilder};
use crate::pim::compile::{CommandCensus, OptLevel, ProgramCache};
use crate::pim::PimOp;
use crate::util::{BitRow, ShiftDir};

pub use crate::pim::program::{PimTape, ProgramSketch};

/// Arithmetic shift direction within elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// toward the MSB (×2 per step) — column-space Right
    Up,
    /// toward the LSB (÷2 per step) — column-space Left
    Down,
}

impl Dir {
    pub fn col(self) -> ShiftDir {
        match self {
            Dir::Up => ShiftDir::Right,
            Dir::Down => ShiftDir::Left,
        }
    }
}

/// An element-wise programming context: a client session against a
/// private single-bank serving system, with one [`RowHandle`] per context
/// row, the element width, and the command census of everything executed.
pub struct ElementCtx {
    pub width: usize,
    pub aaps: usize,
    pub tras: usize,
    pub dras: usize,
    /// scratch-reload AAPs the cross-op fusion peephole elided across
    /// everything executed (0 when the context's cache is unfused);
    /// `aaps + elided_aaps` recovers the unfused calibration totals
    pub elided_aaps: usize,
    cols: usize,
    /// opt level the context's cache compiles at — kernel recordings
    /// follow it so cache keys and compiled programs always agree
    opt: OptLevel,
    client: PimClient,
    rows: Vec<RowHandle>,
}

impl PimTape for ElementCtx {
    fn width(&self) -> usize {
        self.width
    }

    /// Incremental execution: each macro-op is a single-op kernel through
    /// the client path (the reference entry point).
    fn op(&mut self, op: PimOp) {
        ElementCtx::op(self, op);
    }
}

impl ElementCtx {
    /// Context against the process-wide kernel cache and the paper's DDR3
    /// pricing config (the config only prices footprints; functional
    /// behavior depends on `rows`/`cols` alone).
    pub fn new(rows: usize, cols: usize, width: usize) -> Self {
        Self::with_config(
            rows,
            cols,
            width,
            DramConfig::ddr3_1333_4gb(),
            ProgramCache::global(),
        )
    }

    /// Context with an explicit pricing config and kernel cache. The
    /// config's timing/energy model is kept; its geometry is replaced via
    /// [`DramConfig::single_channel`] — a single bank of one `rows × cols`
    /// subarray sized to this context. The opt level follows the cache
    /// ([`ProgramCache::opt_level`]): the process-wide default is level 1
    /// (fused); a level-0 cache serves the paper's literal per-op
    /// lowering, a level-2 cache adds the full pass pipeline
    /// ([`crate::pim::compile::passes`]).
    pub fn with_config(
        rows: usize,
        cols: usize,
        width: usize,
        cfg: DramConfig,
        cache: Arc<ProgramCache>,
    ) -> Self {
        assert!(cols % width == 0, "row must pack whole elements");
        let cfg = cfg.single_channel(rows, cols);
        let opt = cache.opt_level();
        let sys = SystemBuilder::new(&cfg)
            .banks(1)
            .shared_cache(cache)
            .opt_level(opt)
            .build();
        let client = sys.client();
        let handles = client
            .alloc_rows(rows)
            .expect("context rows fit the freshly built subarray");
        ElementCtx {
            width,
            aaps: 0,
            tras: 0,
            dras: 0,
            elided_aaps: 0,
            cols,
            opt,
            client,
            rows: handles,
        }
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn n_elements(&self) -> usize {
        self.cols / self.width
    }

    /// The client session this context executes through.
    pub fn client(&self) -> &PimClient {
        &self.client
    }

    /// The kernel cache this context compiles into.
    pub fn cache(&self) -> Arc<ProgramCache> {
        self.client.system().program_cache().clone()
    }

    /// Execute one macro-op as a single-op kernel (reference entry point).
    pub fn op(&mut self, op: PimOp) {
        self.run(&Kernel::op(op));
    }

    /// Submit a kernel against this context's row table and account its
    /// census.
    fn run(&mut self, kernel: &Kernel) {
        let receipt = self
            .client
            .run(kernel, &self.rows)
            .expect("context kernels execute on the private bank");
        self.count(&receipt.census);
        self.elided_aaps += receipt.elided_aaps as usize;
    }

    fn count(&mut self, census: &CommandCensus) {
        self.aaps += census.aap as usize;
        self.tras += census.tra as usize;
        self.dras += census.dra as usize;
    }

    /// Record the kernel `name` (at most once per shape — the program
    /// cache replays it afterwards) and submit it whole. `params` must pin
    /// down everything the builder's op stream depends on besides
    /// width/cols — operand rows, constants, distances. This is the
    /// compile-once entry all app kernels route through, and it is the
    /// same client path external callers use.
    pub fn run_kernel(
        &mut self,
        name: &'static str,
        params: &[u64],
        build: impl FnOnce(&mut ProgramSketch),
    ) {
        let mut key_params = Vec::with_capacity(params.len() + 1);
        key_params.push(self.cols as u64);
        key_params.extend_from_slice(params);
        let kernel = Kernel::named_opt(name, self.width, &key_params, self.opt, build);
        self.run(&kernel);
    }

    /// Host-write a constant/mask row.
    pub fn set_row(&mut self, row: usize, bits: BitRow) {
        self.client
            .write_now(&self.rows[row], bits)
            .expect("host write to a context row");
    }

    /// Read a row back from the device.
    pub fn row(&self, row: usize) -> BitRow {
        self.client
            .read_now(&self.rows[row])
            .expect("host read of a context row")
    }

    /// Pack u64 element values into a row image.
    pub fn pack(&self, values: &[u64]) -> BitRow {
        assert_eq!(values.len(), self.n_elements());
        let mut r = BitRow::zeros(self.cols());
        for (j, &v) in values.iter().enumerate() {
            assert!(self.width == 64 || v < (1u64 << self.width), "value too wide");
            for i in 0..self.width {
                if (v >> i) & 1 == 1 {
                    r.set(self.width * j + i, true);
                }
            }
        }
        r
    }

    /// Unpack a row image into element values.
    pub fn unpack(&self, r: &BitRow) -> Vec<u64> {
        (0..self.n_elements())
            .map(|j| {
                let mut v = 0u64;
                for i in 0..self.width {
                    if r.get(self.width * j + i) {
                        v |= 1 << i;
                    }
                }
                v
            })
            .collect()
    }

    /// Mask row with 1s at columns where `col % width ∈ bits`.
    pub fn bit_mask(&self, bits: &[usize]) -> BitRow {
        let mut r = BitRow::zeros(self.cols());
        for col in 0..self.cols() {
            if bits.contains(&(col % self.width)) {
                r.set(col, true);
            }
        }
        r
    }

    /// Mask that keeps bits which did NOT cross an element boundary after
    /// an arithmetic shift by `d` in direction `dir`:
    /// Up: keep `col % width >= d`; Down: keep `col % width < width − d`.
    pub fn boundary_mask(&self, dir: Dir, d: usize) -> BitRow {
        let mut r = BitRow::zeros(self.cols());
        for col in 0..self.cols() {
            let i = col % self.width;
            let keep = match dir {
                Dir::Up => i >= d,
                Dir::Down => i < self.width - d,
            };
            if keep {
                r.set(col, true);
            }
        }
        r
    }
}

/// Element-local shift: `dst := (src shifted by d within each element)`.
/// Issues `4·d` AAPs for the row shifts plus one AND against the boundary
/// mask in `mask_row` (which the caller must have initialized with
/// [`ElementCtx::boundary_mask`] for this (dir, d)).
pub fn shift_in_element(
    tape: &mut impl PimTape,
    src: usize,
    dst: usize,
    dir: Dir,
    d: usize,
    mask_row: usize,
) {
    assert!(d < tape.width());
    if d == 0 {
        tape.op(PimOp::Copy { src, dst });
        return;
    }
    tape.op(PimOp::ShiftBy { src, dst, n: d, dir: dir.col() });
    tape.op(PimOp::And { a: dst, b: mask_row, dst });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ctx() -> ElementCtx {
        ElementCtx::new(24, 256, 8)
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let c = ctx();
        let mut rng = Rng::new(1);
        let vals: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        let row = c.pack(&vals);
        assert_eq!(c.unpack(&row), vals);
    }

    #[test]
    fn boundary_masks() {
        let c = ctx();
        let up2 = c.boundary_mask(Dir::Up, 2);
        assert!(!up2.get(0) && !up2.get(1) && up2.get(2) && up2.get(7));
        assert!(!up2.get(8) && up2.get(10));
        let down3 = c.boundary_mask(Dir::Down, 3);
        assert!(down3.get(0) && down3.get(4) && !down3.get(5) && !down3.get(7));
    }

    #[test]
    fn element_shift_up_is_mul2() {
        let mut c = ctx();
        let mut rng = Rng::new(2);
        let vals: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        let row = c.pack(&vals);
        c.set_row(0, row);
        let m = c.boundary_mask(Dir::Up, 1);
        c.set_row(10, m);
        shift_in_element(&mut c, 0, 1, Dir::Up, 1, 10);
        let got = c.unpack(&c.row(1));
        let want: Vec<u64> = vals.iter().map(|v| (v << 1) & 0xFF).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn element_shift_down() {
        let mut c = ctx();
        let vals: Vec<u64> = (0..32).map(|j| (j * 37 + 5) as u64 % 256).collect();
        let row = c.pack(&vals);
        c.set_row(0, row);
        let m = c.boundary_mask(Dir::Down, 3);
        c.set_row(10, m);
        shift_in_element(&mut c, 0, 1, Dir::Down, 3, 10);
        let got = c.unpack(&c.row(1));
        let want: Vec<u64> = vals.iter().map(|v| v >> 3).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn aap_accounting() {
        let mut c = ctx();
        c.set_row(10, c.boundary_mask(Dir::Up, 1));
        let before = c.aaps;
        shift_in_element(&mut c, 0, 1, Dir::Up, 1, 10);
        // 4 AAPs for the shift + 5 for the AND (4 AAP + TRA)
        assert_eq!(c.aaps - before, 8);
        assert_eq!(c.tras, 1);
    }

    #[test]
    fn sketch_records_without_executing() {
        let mut sk = ProgramSketch::new(8);
        shift_in_element(&mut sk, 0, 1, Dir::Up, 2, 10);
        assert_eq!(
            sk.ops(),
            &[
                PimOp::ShiftBy { src: 0, dst: 1, n: 2, dir: ShiftDir::Right },
                PimOp::And { a: 1, b: 10, dst: 1 },
            ]
        );
    }

    #[test]
    fn run_kernel_caches_by_shape_and_matches_incremental_path() {
        let cache = Arc::new(ProgramCache::new(16));
        let cfg = DramConfig::tiny_test();
        let mut rng = Rng::new(9);
        let vals: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();

        let mut tape = ElementCtx::with_config(24, 256, 8, cfg.clone(), cache.clone());
        let mut whole = ElementCtx::with_config(24, 256, 8, cfg.clone(), cache.clone());
        let row_img = tape.pack(&vals);
        let mask = tape.boundary_mask(Dir::Up, 1);
        for c in [&mut tape, &mut whole] {
            c.set_row(0, row_img.clone());
            c.set_row(10, mask.clone());
        }
        // reference: op-by-op through the same client path
        shift_in_element(&mut tape, 0, 1, Dir::Up, 1, 10);
        // whole-kernel submission, twice — the second run must not
        // recompile (memo/cache serve it)
        for _ in 0..2 {
            whole.run_kernel("test.shift1", &[0, 1, 10], |t| {
                shift_in_element(t, 0, 1, Dir::Up, 1, 10)
            });
        }
        assert_eq!(whole.row(1), tape.row(1), "kernel path is bit-exact");
        let s = cache.stats();
        assert_eq!(s.misses, 3, "shift1 kernel + 2 single-op shapes: {s:?}");
        assert_eq!(
            s.hits + s.batched,
            1,
            "repeat kernel served without compiling: {s:?}"
        );
        // census accounting matches the incremental path per run
        assert_eq!(whole.aaps, 2 * tape.aaps);
        assert_eq!(whole.tras, 2 * tape.tras);
    }
}
