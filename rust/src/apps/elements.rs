//! Element-wise PIM programming helpers.
//!
//! Application data is packed horizontally: a row of `cols` bits holds
//! `cols / W` little-endian W-bit elements (bit `i` of element `j` at
//! column `W*j + i` — the conventional horizontal layout the paper's
//! design operates on, no transposition).
//!
//! Because the migration-cell shift moves the *whole row*, element-local
//! shifts are built as `row shift` + `boundary mask`: bits that crossed an
//! element boundary are cleared with a precomputed constant mask row.
//! Mask rows are host-written constants (like Ambit's control rows, they
//! are initialized once at boot).
//!
//! # Kernels are compiled once, executed from the cache
//!
//! Kernel bodies are written against the [`PimTape`] trait — a sink of
//! macro-ops plus the element width. Two tapes exist:
//!
//! * [`ProgramSketch`] records the ops; the entry-point wrappers
//!   (`adder::ripple_add`, `gf::gf_mul`, …) run a sketch **only on a cache
//!   miss**, compile it into a [`CompiledProgram`], and store it in the
//!   shared [`ProgramCache`] keyed by (kernel name, shape parameters,
//!   config fingerprint). Every later invocation with the same shape
//!   replays the cached schedule through the word-level semantic executor.
//! * [`ElementCtx`] itself is a tape that executes eagerly, command by
//!   command — the reference path the cached path is property-tested
//!   against, still used for data-dependent fragments.
//!
//! NOTE on direction names: a column-space `ShiftDir::Right` moves bit `i`
//! to bit `i+1`, i.e. it is the *arithmetic left shift* (×2) of the packed
//! little-endian elements. [`Dir::Up`] / [`Dir::Down`] name the arithmetic
//! directions to keep callers sane.

use std::sync::Arc;

use crate::config::DramConfig;
use crate::dram::subarray::Subarray;
use crate::pim::compile::{CommandCensus, CompiledProgram, ProgramCache, ProgramShape};
use crate::pim::{executor, PimOp};
use crate::util::{BitRow, ShiftDir};

/// Arithmetic shift direction within elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// toward the MSB (×2 per step) — column-space Right
    Up,
    /// toward the LSB (÷2 per step) — column-space Left
    Down,
}

impl Dir {
    pub fn col(self) -> ShiftDir {
        match self {
            Dir::Up => ShiftDir::Right,
            Dir::Down => ShiftDir::Left,
        }
    }
}

/// A sink of macro-ops over W-bit elements: kernel bodies are generic over
/// this, so the same body either executes eagerly ([`ElementCtx`]) or
/// records into a cacheable program ([`ProgramSketch`]).
pub trait PimTape {
    /// Element width the kernel is being built for.
    fn width(&self) -> usize;
    /// Accept one macro-op.
    fn op(&mut self, op: PimOp);
}

/// Recording tape: collects the macro-op schedule of one kernel shape.
pub struct ProgramSketch {
    width: usize,
    ops: Vec<PimOp>,
}

impl ProgramSketch {
    pub fn new(width: usize) -> Self {
        ProgramSketch { width, ops: Vec::new() }
    }

    pub fn ops(&self) -> &[PimOp] {
        &self.ops
    }

    pub fn into_ops(self) -> Vec<PimOp> {
        self.ops
    }
}

impl PimTape for ProgramSketch {
    fn width(&self) -> usize {
        self.width
    }

    fn op(&mut self, op: PimOp) {
        self.ops.push(op);
    }
}

/// A subarray "tape" for element-wise programs: tracks the subarray, the
/// element width, the command census of everything executed, and the
/// program cache its kernels compile into.
pub struct ElementCtx {
    pub sa: Subarray,
    pub width: usize,
    pub aaps: usize,
    pub tras: usize,
    pub dras: usize,
    cfg: DramConfig,
    cfg_fp: u64,
    cache: Arc<ProgramCache>,
}

impl PimTape for ElementCtx {
    fn width(&self) -> usize {
        self.width
    }

    /// Eager execution: lower and apply immediately (the reference path).
    fn op(&mut self, op: PimOp) {
        ElementCtx::op(self, op);
    }
}

impl ElementCtx {
    /// Context against the process-wide kernel cache and the paper's DDR3
    /// pricing config (the config only prices footprints; functional
    /// behavior depends on `rows`/`cols` alone).
    pub fn new(rows: usize, cols: usize, width: usize) -> Self {
        Self::with_config(
            rows,
            cols,
            width,
            DramConfig::ddr3_1333_4gb(),
            ProgramCache::global(),
        )
    }

    /// Context with an explicit pricing config and kernel cache.
    pub fn with_config(
        rows: usize,
        cols: usize,
        width: usize,
        cfg: DramConfig,
        cache: Arc<ProgramCache>,
    ) -> Self {
        assert!(cols % width == 0, "row must pack whole elements");
        let cfg_fp = cfg.fingerprint();
        ElementCtx {
            sa: Subarray::new(rows, cols),
            width,
            aaps: 0,
            tras: 0,
            dras: 0,
            cfg,
            cfg_fp,
            cache,
        }
    }

    pub fn cols(&self) -> usize {
        self.sa.cols()
    }

    pub fn n_elements(&self) -> usize {
        self.cols() / self.width
    }

    /// The kernel cache this context compiles into.
    pub fn cache(&self) -> &Arc<ProgramCache> {
        &self.cache
    }

    /// Execute one macro-op eagerly, accounting commands (reference path).
    pub fn op(&mut self, op: PimOp) {
        let cmds = op.lower();
        self.count(&CommandCensus::from_commands(&cmds));
        executor::run(&mut self.sa, &cmds);
    }

    fn count(&mut self, census: &CommandCensus) {
        self.aaps += census.aap as usize;
        self.tras += census.tra as usize;
        self.dras += census.dra as usize;
    }

    /// Fetch (or, on first use of this shape, record + compile) the kernel
    /// `name` and execute it. `params` must pin down everything the
    /// builder's op stream depends on besides width/cols — operand rows,
    /// constants, distances. This is the compile-once entry all app
    /// kernels route through.
    pub fn run_kernel(
        &mut self,
        name: &'static str,
        params: &[u64],
        build: impl FnOnce(&mut ProgramSketch),
    ) {
        let mut key_params = Vec::with_capacity(params.len() + 2);
        key_params.push(self.width as u64);
        key_params.push(self.cols() as u64);
        key_params.extend_from_slice(params);
        let shape = ProgramShape::Kernel { name, params: key_params };
        let width = self.width;
        let prog = self.cache.get_or_compile_keyed(shape, &self.cfg, self.cfg_fp, || {
            let mut sketch = ProgramSketch::new(width);
            build(&mut sketch);
            sketch.into_ops()
        });
        self.execute(&prog);
    }

    /// Execute a compiled program (identity binding) through the word-level
    /// semantic executor, accounting its census in O(1).
    pub fn execute(&mut self, prog: &CompiledProgram) {
        executor::run_compiled(&mut self.sa, prog, None);
        let census = *prog.census();
        self.count(&census);
    }

    /// Host-write a constant/mask row.
    pub fn set_row(&mut self, row: usize, bits: BitRow) {
        self.sa.write_row(row, bits);
    }

    pub fn row(&self, row: usize) -> &BitRow {
        self.sa.read_row(row)
    }

    /// Pack u64 element values into a row image.
    pub fn pack(&self, values: &[u64]) -> BitRow {
        assert_eq!(values.len(), self.n_elements());
        let mut r = BitRow::zeros(self.cols());
        for (j, &v) in values.iter().enumerate() {
            assert!(self.width == 64 || v < (1u64 << self.width), "value too wide");
            for i in 0..self.width {
                if (v >> i) & 1 == 1 {
                    r.set(self.width * j + i, true);
                }
            }
        }
        r
    }

    /// Unpack a row image into element values.
    pub fn unpack(&self, r: &BitRow) -> Vec<u64> {
        (0..self.n_elements())
            .map(|j| {
                let mut v = 0u64;
                for i in 0..self.width {
                    if r.get(self.width * j + i) {
                        v |= 1 << i;
                    }
                }
                v
            })
            .collect()
    }

    /// Mask row with 1s at columns where `col % width ∈ bits`.
    pub fn bit_mask(&self, bits: &[usize]) -> BitRow {
        let mut r = BitRow::zeros(self.cols());
        for col in 0..self.cols() {
            if bits.contains(&(col % self.width)) {
                r.set(col, true);
            }
        }
        r
    }

    /// Mask that keeps bits which did NOT cross an element boundary after
    /// an arithmetic shift by `d` in direction `dir`:
    /// Up: keep `col % width >= d`; Down: keep `col % width < width − d`.
    pub fn boundary_mask(&self, dir: Dir, d: usize) -> BitRow {
        let mut r = BitRow::zeros(self.cols());
        for col in 0..self.cols() {
            let i = col % self.width;
            let keep = match dir {
                Dir::Up => i >= d,
                Dir::Down => i < self.width - d,
            };
            if keep {
                r.set(col, true);
            }
        }
        r
    }
}

/// Element-local shift: `dst := (src shifted by d within each element)`.
/// Issues `4·d` AAPs for the row shifts plus one AND against the boundary
/// mask in `mask_row` (which the caller must have initialized with
/// [`ElementCtx::boundary_mask`] for this (dir, d)).
pub fn shift_in_element(
    tape: &mut impl PimTape,
    src: usize,
    dst: usize,
    dir: Dir,
    d: usize,
    mask_row: usize,
) {
    assert!(d < tape.width());
    if d == 0 {
        tape.op(PimOp::Copy { src, dst });
        return;
    }
    tape.op(PimOp::ShiftBy { src, dst, n: d, dir: dir.col() });
    tape.op(PimOp::And { a: dst, b: mask_row, dst });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ctx() -> ElementCtx {
        ElementCtx::new(24, 256, 8)
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let c = ctx();
        let mut rng = Rng::new(1);
        let vals: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        let row = c.pack(&vals);
        assert_eq!(c.unpack(&row), vals);
    }

    #[test]
    fn boundary_masks() {
        let c = ctx();
        let up2 = c.boundary_mask(Dir::Up, 2);
        assert!(!up2.get(0) && !up2.get(1) && up2.get(2) && up2.get(7));
        assert!(!up2.get(8) && up2.get(10));
        let down3 = c.boundary_mask(Dir::Down, 3);
        assert!(down3.get(0) && down3.get(4) && !down3.get(5) && !down3.get(7));
    }

    #[test]
    fn element_shift_up_is_mul2() {
        let mut c = ctx();
        let mut rng = Rng::new(2);
        let vals: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        let row = c.pack(&vals);
        c.set_row(0, row);
        let m = c.boundary_mask(Dir::Up, 1);
        c.set_row(10, m);
        shift_in_element(&mut c, 0, 1, Dir::Up, 1, 10);
        let got = c.unpack(c.row(1));
        let want: Vec<u64> = vals.iter().map(|v| (v << 1) & 0xFF).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn element_shift_down() {
        let mut c = ctx();
        let vals: Vec<u64> = (0..32).map(|j| (j * 37 + 5) as u64 % 256).collect();
        let row = c.pack(&vals);
        c.set_row(0, row);
        let m = c.boundary_mask(Dir::Down, 3);
        c.set_row(10, m);
        shift_in_element(&mut c, 0, 1, Dir::Down, 3, 10);
        let got = c.unpack(c.row(1));
        let want: Vec<u64> = vals.iter().map(|v| v >> 3).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn aap_accounting() {
        let mut c = ctx();
        c.set_row(10, c.boundary_mask(Dir::Up, 1));
        let before = c.aaps;
        shift_in_element(&mut c, 0, 1, Dir::Up, 1, 10);
        // 4 AAPs for the shift + 5 for the AND (4 AAP + TRA)
        assert_eq!(c.aaps - before, 8);
        assert_eq!(c.tras, 1);
    }

    #[test]
    fn sketch_records_without_executing() {
        let mut sk = ProgramSketch::new(8);
        shift_in_element(&mut sk, 0, 1, Dir::Up, 2, 10);
        assert_eq!(
            sk.ops(),
            &[
                PimOp::ShiftBy { src: 0, dst: 1, n: 2, dir: ShiftDir::Right },
                PimOp::And { a: 1, b: 10, dst: 1 },
            ]
        );
    }

    #[test]
    fn run_kernel_caches_by_shape_and_matches_eager_path() {
        let cache = Arc::new(ProgramCache::new(16));
        let cfg = DramConfig::tiny_test();
        let mut rng = Rng::new(9);
        let vals: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();

        let mut eager = ElementCtx::with_config(24, 256, 8, cfg.clone(), cache.clone());
        let mut cached = ElementCtx::with_config(24, 256, 8, cfg.clone(), cache.clone());
        let row_img = eager.pack(&vals);
        let mask = eager.boundary_mask(Dir::Up, 1);
        for c in [&mut eager, &mut cached] {
            c.set_row(0, row_img.clone());
            c.set_row(10, mask.clone());
        }
        // reference: eager tape
        shift_in_element(&mut eager, 0, 1, Dir::Up, 1, 10);
        // cached kernel, twice — second run must be a cache hit
        for _ in 0..2 {
            cached.run_kernel("test.shift1", &[0, 1, 10], |t| {
                shift_in_element(t, 0, 1, Dir::Up, 1, 10)
            });
        }
        assert_eq!(cached.row(1), eager.row(1), "cached path is bit-exact");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1), "{s:?}");
        // census accounting matches the eager path per run
        assert_eq!(cached.aaps, 2 * eager.aaps);
        assert_eq!(cached.tras, 2 * eager.tras);
    }
}
