//! GF(2⁸) arithmetic in DRAM — the paper's cryptography motivation
//! (§1, §8.0.2): polynomial multiplication and reduction are shift + XOR,
//! exactly the primitives the migration-cell design provides.
//!
//! The row packs 8-bit field elements (AES polynomial x⁸+x⁴+x³+x+1,
//! i.e. reduction constant 0x1B). `xtime` (×x) is: shift-up by one inside
//! each byte, then conditionally XOR 0x1B into bytes whose MSB was set —
//! the condition is materialized by *spreading* the carried-out MSB to the
//! 0x1B bit positions with further shifts (everything stays in-DRAM).
//!
//! Entry points ([`xtime`], [`gf_mul_const`], [`gf_mul`]) are cached
//! kernels; the `build_*` bodies record the schedule once per shape and
//! compose into the AES and Reed-Solomon kernels.
//!
//! Row map: 0..=2 operands/result, 3..7 adder temps (shared), 8..15
//! boundary masks, 16..19 GF temporaries, 20..23 GF constant masks.

use crate::apps::adder::{install_masks, mask_row_for_dir};
use crate::apps::elements::{shift_in_element, Dir, ElementCtx, PimTape};
use crate::pim::PimOp;

const T_SH: usize = 16;
const T_CARRY: usize = 17;
const T_RED: usize = 18;
const T_SPREAD: usize = 19;
/// mask of each byte's MSB (bit 7)
const M_MSB: usize = 20;
/// accumulator and peasant-loop temporaries for full gf_mul
const T_ACC: usize = 22;
const T_AA: usize = 23;
const T_BB: usize = 24;
const T_LSB: usize = 25;
const T_COND: usize = 26;
/// mask of each byte's LSB (bit 0)
const M_LSB: usize = 27;

/// Host-side one-time setup of GF masks (plus the adder boundary masks).
pub fn install_gf_masks(ctx: &mut ElementCtx) {
    assert_eq!(ctx.width, 8, "GF(2^8) works on byte elements");
    install_masks(ctx);
    ctx.set_row(M_MSB, ctx.bit_mask(&[7]));
    ctx.set_row(M_LSB, ctx.bit_mask(&[0]));
}

/// Spread a bit-0 flag to a set of bit positions within each byte:
/// `dst := OR over p in positions of (src << p)` (src must have data only
/// at bit 0 of each byte).
fn spread_bits(tape: &mut impl PimTape, src: usize, dst: usize, positions: &[usize]) {
    tape.op(PimOp::SetZero { dst });
    for &p in positions {
        if p == 0 {
            tape.op(PimOp::Or { a: dst, b: src, dst });
        } else {
            shift_any(tape, src, T_SPREAD, Dir::Up, p);
            tape.op(PimOp::Or { a: dst, b: T_SPREAD, dst });
        }
    }
}

/// Element shift by arbitrary distance d, composing the power-of-two
/// stages whose boundary masks [`install_masks`] provided.
fn shift_any(tape: &mut impl PimTape, src: usize, dst: usize, dir: Dir, d: usize) {
    assert!(d < tape.width());
    if d == 0 {
        tape.op(PimOp::Copy { src, dst });
        return;
    }
    let mut remaining = d;
    let mut stage = 1usize;
    let mut cur = src;
    while remaining > 0 {
        if remaining & 1 == 1 {
            shift_in_element(tape, cur, dst, dir, stage, mask_row_for_dir(dir, stage));
            cur = dst;
        }
        remaining >>= 1;
        stage *= 2;
    }
}

/// `dst := xtime(src)` (multiply by x in GF(2⁸)). Cached per shape.
pub fn xtime(ctx: &mut ElementCtx, src: usize, dst: usize) {
    ctx.run_kernel("gf.xtime", &[src as u64, dst as u64], |t| build_xtime(t, src, dst));
}

/// Emit the xtime schedule onto a tape.
pub fn build_xtime(tape: &mut impl PimTape, src: usize, dst: usize) {
    for t in [T_SH, T_CARRY, T_RED, T_SPREAD] {
        tape.scratch(t);
    }
    // carry = bytes whose bit 7 is set, flag at bit 0
    tape.op(PimOp::And { a: src, b: M_MSB, dst: T_CARRY });
    shift_any(tape, T_CARRY, T_CARRY, Dir::Down, 7);
    // shifted = (src << 1) within bytes
    shift_in_element(tape, src, T_SH, Dir::Up, 1, mask_row_for_dir(Dir::Up, 1));
    // reduction row: 0x1B = bits {0,1,3,4} where carry
    spread_bits(tape, T_CARRY, T_RED, &[0, 1, 3, 4]);
    tape.op(PimOp::Xor { a: T_SH, b: T_RED, dst });
}

/// `dst := src ⊗ k` for a compile-time constant k (chain of xtime + XOR —
/// how AES MixColumns consumes ×2 and ×3). Cached per (shape, k).
pub fn gf_mul_const(ctx: &mut ElementCtx, src: usize, dst: usize, k: u8) {
    ctx.run_kernel(
        "gf.mul_const",
        &[src as u64, dst as u64, k as u64],
        |t| build_gf_mul_const(t, src, dst, k),
    );
}

/// Emit the constant-multiply schedule onto a tape.
pub fn build_gf_mul_const(tape: &mut impl PimTape, src: usize, dst: usize, k: u8) {
    assert!(k > 0);
    tape.scratch(T_ACC);
    tape.scratch(T_AA);
    // Russian peasant with the constant known at build time:
    // acc = Σ_(bits of k) xtime^i(src)
    tape.op(PimOp::SetZero { dst: T_ACC });
    tape.op(PimOp::Copy { src, dst: T_AA });
    let mut kk = k;
    while kk != 0 {
        if kk & 1 == 1 {
            tape.op(PimOp::Xor { a: T_ACC, b: T_AA, dst: T_ACC });
        }
        kk >>= 1;
        if kk != 0 {
            build_xtime(tape, T_AA, T_AA);
        }
    }
    tape.op(PimOp::Copy { src: T_ACC, dst });
}

/// Full vector `dst := a ⊗ b` (both rows of packed bytes): Russian-peasant
/// multiplication with the per-byte condition bit broadcast in-DRAM.
/// Cached per shape.
pub fn gf_mul(ctx: &mut ElementCtx, row_a: usize, row_b: usize, dst: usize) {
    ctx.run_kernel(
        "gf.mul",
        &[row_a as u64, row_b as u64, dst as u64],
        |t| build_gf_mul(t, row_a, row_b, dst),
    );
}

/// Emit the full-multiply schedule onto a tape.
pub fn build_gf_mul(tape: &mut impl PimTape, row_a: usize, row_b: usize, dst: usize) {
    for t in [T_ACC, T_AA, T_BB, T_LSB, T_COND] {
        tape.scratch(t);
    }
    tape.op(PimOp::SetZero { dst: T_ACC });
    tape.op(PimOp::Copy { src: row_a, dst: T_AA });
    tape.op(PimOp::Copy { src: row_b, dst: T_BB });
    for i in 0..8 {
        // cond = bytes of b with bit0 set, broadcast to all 8 positions
        tape.op(PimOp::And { a: T_BB, b: M_LSB, dst: T_LSB });
        spread_bits(tape, T_LSB, T_COND, &[0, 1, 2, 3, 4, 5, 6, 7]);
        // acc ^= a & cond
        tape.op(PimOp::And { a: T_AA, b: T_COND, dst: T_COND });
        tape.op(PimOp::Xor { a: T_ACC, b: T_COND, dst: T_ACC });
        if i < 7 {
            build_xtime(tape, T_AA, T_AA);
            shift_any(tape, T_BB, T_BB, Dir::Down, 1);
        }
    }
    tape.op(PimOp::Copy { src: T_ACC, dst });
}

/// Host-side reference: GF(2⁸) multiply (AES polynomial).
pub fn gf_mul_ref(mut a: u8, mut b: u8) -> u8 {
    let mut acc = 0u8;
    while b != 0 {
        if b & 1 == 1 {
            acc ^= a;
        }
        let hi = a & 0x80 != 0;
        a <<= 1;
        if hi {
            a ^= 0x1B;
        }
        b >>= 1;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn setup() -> ElementCtx {
        let mut ctx = ElementCtx::new(40, 256, 8);
        install_gf_masks(&mut ctx);
        ctx
    }

    #[test]
    fn xtime_matches_reference() {
        let mut ctx = setup();
        let vals: Vec<u64> = (0..32).map(|j| (j * 8 + 3) as u64 % 256).collect();
        ctx.set_row(0, ctx.pack(&vals));
        xtime(&mut ctx, 0, 1);
        let got = ctx.unpack(&ctx.row(1));
        let want: Vec<u64> = vals.iter().map(|&v| gf_mul_ref(v as u8, 2) as u64).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn xtime_with_and_without_reduction() {
        let mut ctx = setup();
        let mut vals = vec![0u64; 32];
        vals[0] = 0x80; // reduces: 0x80*2 = 0x1B
        vals[1] = 0x40; // no reduction: 0x80
        vals[2] = 0xFF;
        ctx.set_row(0, ctx.pack(&vals));
        xtime(&mut ctx, 0, 1);
        let got = ctx.unpack(&ctx.row(1));
        assert_eq!(got[0], 0x1B);
        assert_eq!(got[1], 0x80);
        assert_eq!(got[2], (0xFFu64 * 2 ^ 0x11B) & 0xFF);
    }

    #[test]
    fn mul_const_3_is_xtime_xor_identity() {
        let mut ctx = setup();
        let mut rng = Rng::new(4);
        let vals: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        ctx.set_row(0, ctx.pack(&vals));
        gf_mul_const(&mut ctx, 0, 1, 3);
        let got = ctx.unpack(&ctx.row(1));
        let want: Vec<u64> = vals.iter().map(|&v| gf_mul_ref(v as u8, 3) as u64).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn mul_const_various_k() {
        let mut ctx = setup();
        let vals: Vec<u64> = (0..32).map(|j| (j * 11 + 1) as u64 % 256).collect();
        for k in [1u8, 2, 9, 0x0E, 0x1D, 0x80] {
            ctx.set_row(0, ctx.pack(&vals));
            gf_mul_const(&mut ctx, 0, 1, k);
            let got = ctx.unpack(&ctx.row(1));
            let want: Vec<u64> =
                vals.iter().map(|&v| gf_mul_ref(v as u8, k) as u64).collect();
            assert_eq!(got, want, "k={k:#x}");
        }
    }

    #[test]
    fn full_vector_multiply() {
        let mut ctx = setup();
        let mut rng = Rng::new(7);
        let a: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        let b: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        ctx.set_row(0, ctx.pack(&a));
        ctx.set_row(1, ctx.pack(&b));
        gf_mul(&mut ctx, 0, 1, 2);
        let got = ctx.unpack(&ctx.row(2));
        let want: Vec<u64> = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| gf_mul_ref(x as u8, y as u8) as u64)
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn cached_and_eager_paths_agree() {
        // the same kernel body through the recording tape (cached,
        // semantic executor) and the eager tape (per-command executor).
        // Pinned to opt level 1: the elided-AAP reconciliation below is a
        // property of the fused lowering alone — level 2 also rewrites the
        // op stream, which the per-op eager path can't mirror.
        use crate::config::DramConfig;
        use crate::pim::compile::ProgramCache;
        use std::sync::Arc;
        let o1 = |cache: Arc<ProgramCache>| {
            let mut c =
                ElementCtx::with_config(40, 256, 8, DramConfig::ddr3_1333_4gb(), cache);
            install_gf_masks(&mut c);
            c
        };
        let mut cached = o1(Arc::new(ProgramCache::new_fused(64)));
        let mut eager = o1(Arc::new(ProgramCache::new_fused(64)));
        let mut rng = Rng::new(17);
        let a: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        let b: Vec<u64> = (0..32).map(|_| rng.below(256) as u64).collect();
        cached.set_row(0, cached.pack(&a));
        cached.set_row(1, cached.pack(&b));
        eager.set_row(0, eager.pack(&a));
        eager.set_row(1, eager.pack(&b));
        gf_mul(&mut cached, 0, 1, 2);
        build_gf_mul(&mut eager, 0, 1, 2); // ElementCtx is the eager tape
        assert_eq!(cached.row(2), eager.row(2));
        // fused-default re-baseline: the whole-kernel path compiles with
        // the cross-op AAP peephole, the per-op eager path cannot fuse
        // across its single-op programs — the elided count reconciles the
        // two censuses exactly
        assert!(cached.elided_aaps > 0, "gf_mul's chained logic ops must fuse");
        assert_eq!(eager.elided_aaps, 0, "single-op programs have nothing to fuse");
        assert_eq!(
            cached.aaps + cached.elided_aaps,
            eager.aaps,
            "fused + elided recovers the unfused census"
        );
        assert_eq!(cached.tras, eager.tras);
        assert_eq!(cached.dras, eager.dras);
    }

    #[test]
    fn gf_mul_ref_sanity() {
        // known AES values
        assert_eq!(gf_mul_ref(0x57, 0x83), 0xC1);
        assert_eq!(gf_mul_ref(0x57, 0x13), 0xFE);
        assert_eq!(gf_mul_ref(1, 0xAB), 0xAB);
    }
}
