//! AES round steps in DRAM (§8.0.2's cryptographic case study).
//!
//! Layout: structure-of-arrays. The 16 AES state bytes live in 16 *rows*;
//! row `r` packs byte `r` of many independent AES blocks side by side
//! (one byte per 8 columns). Every AES step then becomes whole-row PIM
//! operations applied to thousands of blocks at once:
//!
//! * **AddRoundKey** — row XOR against 16 key rows,
//! * **ShiftRows**   — a permutation of row indices (RowClones),
//! * **MixColumns / InvMixColumns** — GF(2⁸) constant multiplies (xtime
//!   chains = migration-cell shifts) and XOR accumulation.
//!
//! Every step is a cached kernel: the full MixColumns schedule (~3k
//! macro-ops of xtime chains) compiles once per shape and replays from
//! the program cache on every round of every batch.
//!
//! SubBytes is deliberately out of scope: an 8→8-bit S-box lookup is a
//! 256-entry table per byte, which neither the paper's design nor Ambit
//! provides a primitive for (bit-sliced S-box circuits are possible but
//! orthogonal to the shift contribution; see DESIGN.md §Limitations).

use crate::apps::elements::{ElementCtx, PimTape};
use crate::apps::gf::{build_gf_mul_const, gf_mul_ref};
use crate::pim::PimOp;

/// Row map: rows 0–30 are reserved by the GF layer (adder temps, boundary
/// masks, GF masks/temporaries — see gf.rs); AES state rows sit above:
/// state 40–55, round keys 56–71, output staging 72–87, mix temps 88+.
/// AES contexts must allocate ≥ 96 rows.
pub const STATE_BASE: usize = 40;
pub const KEY_BASE: usize = 56;
pub const OUT_BASE: usize = 72;
pub const T_MIX: [usize; 4] = [88, 89, 90, 91];
pub const T_ACC: usize = 92;

/// One-time setup: GF masks + adder masks (state rows left untouched).
pub fn install_aes(ctx: &mut ElementCtx) {
    crate::apps::gf::install_gf_masks(ctx);
}

/// AddRoundKey: state[r] ^= key[r] for all 16 rows. Cached.
pub fn add_round_key(ctx: &mut ElementCtx) {
    ctx.run_kernel("aes.add_round_key", &[], |t| build_add_round_key(t));
}

fn build_add_round_key(tape: &mut impl PimTape) {
    for r in 0..16 {
        tape.op(PimOp::Xor { a: STATE_BASE + r, b: KEY_BASE + r, dst: STATE_BASE + r });
    }
}

/// ShiftRows: AES's byte rotation of state rows 1–3 becomes a pure row
/// permutation (RowClones through a staging row). State byte index is
/// `4*col + row` (column-major, as in FIPS-197). Cached.
pub fn shift_rows(ctx: &mut ElementCtx) {
    ctx.run_kernel("aes.shift_rows", &[], |t| build_shift_rows(t));
}

fn build_shift_rows(tape: &mut impl PimTape) {
    // new[row, col] = old[row, (col + row) % 4]
    for row in 1..4 {
        // rotate the 4 rows {row, row+4, row+8, row+12} left by `row`
        let idx: Vec<usize> = (0..4).map(|col| STATE_BASE + 4 * col + row).collect();
        // stage the rotated images
        for col in 0..4 {
            let src = idx[(col + row) % 4];
            tape.op(PimOp::Copy { src, dst: OUT_BASE + col });
        }
        for col in 0..4 {
            tape.op(PimOp::Copy { src: OUT_BASE + col, dst: idx[col] });
        }
    }
}

/// MixColumns with coefficient matrix rows `coef` (e.g. [2,3,1,1] for
/// encryption, [0x0E,0x0B,0x0D,0x09] for decryption).
fn mix_columns_with(ctx: &mut ElementCtx, coef: [u8; 4]) {
    let packed = u64::from_le_bytes([coef[0], coef[1], coef[2], coef[3], 0, 0, 0, 0]);
    ctx.run_kernel("aes.mix_columns", &[packed], |t| build_mix_columns_with(t, coef));
}

/// Emit the MixColumns schedule for coefficient rows `coef` onto a tape
/// (public like the other app builders, so it composes into larger
/// kernels and the compile-pipeline bench can record it directly).
pub fn build_mix_columns_with(tape: &mut impl PimTape, coef: [u8; 4]) {
    // mix temps and the accumulator are dead once the staged outputs are
    // copied back into the state rows (the GF layer declares its own temps)
    for t in T_MIX {
        tape.scratch(t);
    }
    tape.scratch(T_ACC);
    for col in 0..4 {
        let s = |r: usize| STATE_BASE + 4 * col + r;
        for out_r in 0..4 {
            tape.op(PimOp::SetZero { dst: T_ACC });
            for in_r in 0..4 {
                let k = coef[(4 + in_r - out_r) % 4];
                if k == 1 {
                    tape.op(PimOp::Xor { a: T_ACC, b: s(in_r), dst: T_ACC });
                } else {
                    build_gf_mul_const(tape, s(in_r), T_MIX[0], k);
                    tape.op(PimOp::Xor { a: T_ACC, b: T_MIX[0], dst: T_ACC });
                }
            }
            tape.op(PimOp::Copy { src: T_ACC, dst: OUT_BASE + 4 * col + out_r });
        }
    }
    for r in 0..16 {
        tape.op(PimOp::Copy { src: OUT_BASE + r, dst: STATE_BASE + r });
    }
}

pub fn mix_columns(ctx: &mut ElementCtx) {
    mix_columns_with(ctx, [2, 3, 1, 1]);
}

pub fn inv_mix_columns(ctx: &mut ElementCtx) {
    mix_columns_with(ctx, [0x0E, 0x0B, 0x0D, 0x09]);
}

/// Host reference of MixColumns on one 16-byte state (column-major).
pub fn mix_columns_ref(state: &[u8; 16]) -> [u8; 16] {
    let mut out = [0u8; 16];
    for col in 0..4 {
        for r in 0..4 {
            let coef = [2u8, 3, 1, 1];
            let mut acc = 0u8;
            for i in 0..4 {
                acc ^= gf_mul_ref(state[4 * col + i], coef[(4 + i - r) % 4]);
            }
            out[4 * col + r] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// blocks per row = cols/8
    fn setup() -> ElementCtx {
        let mut ctx = ElementCtx::new(96, 128, 8);
        install_aes(&mut ctx);
        ctx
    }

    fn load_states(ctx: &mut ElementCtx, states: &[[u8; 16]]) {
        let n = ctx.n_elements();
        assert_eq!(states.len(), n);
        for r in 0..16 {
            let vals: Vec<u64> = states.iter().map(|s| s[r] as u64).collect();
            ctx.set_row(STATE_BASE + r, ctx.pack(&vals));
        }
    }

    fn read_states(ctx: &ElementCtx) -> Vec<[u8; 16]> {
        let n = ctx.n_elements();
        let mut out = vec![[0u8; 16]; n];
        for r in 0..16 {
            let vals = ctx.unpack(&ctx.row(STATE_BASE + r));
            for (j, &v) in vals.iter().enumerate() {
                out[j][r] = v as u8;
            }
        }
        out
    }

    #[test]
    fn mix_columns_matches_reference() {
        let mut ctx = setup();
        let mut rng = Rng::new(11);
        let n = ctx.n_elements();
        let states: Vec<[u8; 16]> = (0..n)
            .map(|_| {
                let mut s = [0u8; 16];
                for b in &mut s {
                    *b = rng.below(256) as u8;
                }
                s
            })
            .collect();
        load_states(&mut ctx, &states);
        mix_columns(&mut ctx);
        let got = read_states(&ctx);
        for (j, s) in states.iter().enumerate() {
            assert_eq!(got[j], mix_columns_ref(s), "block {j}");
        }
    }

    #[test]
    fn fips197_mix_columns_vector() {
        // FIPS-197 example column: db 13 53 45 -> 8e 4d a1 bc
        let mut ctx = setup();
        let n = ctx.n_elements();
        let mut state = [0u8; 16];
        state[0..4].copy_from_slice(&[0xDB, 0x13, 0x53, 0x45]);
        let states = vec![state; n];
        load_states(&mut ctx, &states);
        mix_columns(&mut ctx);
        let got = read_states(&ctx);
        assert_eq!(&got[0][0..4], &[0x8E, 0x4D, 0xA1, 0xBC]);
    }

    #[test]
    fn inv_mix_columns_inverts() {
        let mut ctx = setup();
        let mut rng = Rng::new(12);
        let n = ctx.n_elements();
        let states: Vec<[u8; 16]> = (0..n)
            .map(|_| {
                let mut s = [0u8; 16];
                for b in &mut s {
                    *b = rng.below(256) as u8;
                }
                s
            })
            .collect();
        load_states(&mut ctx, &states);
        mix_columns(&mut ctx);
        inv_mix_columns(&mut ctx);
        assert_eq!(read_states(&ctx), states);
    }

    #[test]
    fn add_round_key_is_xor_involution() {
        let mut ctx = setup();
        let mut rng = Rng::new(13);
        let n = ctx.n_elements();
        let states: Vec<[u8; 16]> = (0..n)
            .map(|j| {
                let mut s = [0u8; 16];
                for (i, b) in s.iter_mut().enumerate() {
                    *b = ((j * 16 + i) % 256) as u8;
                }
                s
            })
            .collect();
        load_states(&mut ctx, &states);
        for r in 0..16 {
            let key: Vec<u64> = (0..n).map(|_| rng.below(256) as u64).collect();
            ctx.set_row(KEY_BASE + r, ctx.pack(&key));
        }
        add_round_key(&mut ctx);
        add_round_key(&mut ctx);
        assert_eq!(read_states(&ctx), states);
    }

    #[test]
    fn shift_rows_permutation() {
        let mut ctx = setup();
        let n = ctx.n_elements();
        // distinct byte per position so the permutation is visible
        let states: Vec<[u8; 16]> = (0..n)
            .map(|_| core::array::from_fn(|i| i as u8))
            .collect();
        load_states(&mut ctx, &states);
        shift_rows(&mut ctx);
        let got = read_states(&ctx);
        // FIPS-197: row r rotates left by r; byte index = 4*col + row
        let mut want = [0u8; 16];
        for col in 0..4 {
            for row in 0..4 {
                want[4 * col + row] = (4 * ((col + row) % 4) + row) as u8;
            }
        }
        assert_eq!(got[0], want);
    }
}
