//! In-DRAM adders over packed W-bit elements — the paper's §8.0.1
//! extension, built on MAJ/XOR/AND and the migration-cell shift.
//!
//! Two designs:
//! * **Ripple-carry**: W iterations of `c ← shift_up(G | (P & c))`
//! * **Kogge-Stone**: log₂W parallel-prefix rounds
//!   `G ← G | (P & shift_up_d(G)); P ← P & shift_up_d(P)` with doubling d
//!
//! Both use element-boundary masks so carries never cross elements (each
//! element adds independently, SIMD-style across the row).
//!
//! The public entry points ([`ripple_add`], [`kogge_stone_add`]) are
//! compile-once: the schedule is recorded by the `build_*` body, submitted
//! through the serving client as **one kernel** (one cache fetch, one
//! replay), and replayed from the shared program cache on every later
//! call. The `build_*` functions stay public — they compose into larger
//! cached kernels (see `multiplier`).
//!
//! Row map (within the app's subarray): rows 0..=2 inputs/output,
//! 3..=7 temporaries, 8..=15 boundary masks, 16+ scratch.

use crate::apps::elements::{shift_in_element, Dir, ElementCtx, PimTape};
use crate::pim::PimOp;

/// Temporary/mask row assignments.
const T_G: usize = 3;
const T_P: usize = 4;
const T_C: usize = 5;
const T_S: usize = 6;
const T_X: usize = 7;
/// boundary-mask rows for power-of-two shift distances, per direction
const MASK_UP_BASE: usize = 8;
const MASK_DOWN_BASE: usize = 28;

/// Mask row holding the boundary mask for (dir, d) — d a power of two.
pub fn mask_row_for_dir(dir: Dir, d: usize) -> usize {
    debug_assert!(d.is_power_of_two());
    let base = match dir {
        Dir::Up => MASK_UP_BASE,
        Dir::Down => MASK_DOWN_BASE,
    };
    base + d.trailing_zeros() as usize
}

fn mask_row_for(d: usize) -> usize {
    mask_row_for_dir(Dir::Up, d)
}

/// Install the boundary masks adders/GF kernels need (host-side, once).
pub fn install_masks(ctx: &mut ElementCtx) {
    let mut d = 1;
    while d < ctx.width {
        ctx.set_row(mask_row_for_dir(Dir::Up, d), ctx.boundary_mask(Dir::Up, d));
        ctx.set_row(mask_row_for_dir(Dir::Down, d), ctx.boundary_mask(Dir::Down, d));
        d *= 2;
    }
}

/// Ripple-carry add: `row_out := row_a + row_b` (mod 2^W per element).
/// Cost: O(W) shift+logic iterations. Cached per shape.
pub fn ripple_add(ctx: &mut ElementCtx, row_a: usize, row_b: usize, row_out: usize) {
    ctx.run_kernel(
        "adder.ripple",
        &[row_a as u64, row_b as u64, row_out as u64],
        |t| build_ripple_add(t, row_a, row_b, row_out),
    );
}

/// Emit the ripple-carry schedule onto a tape.
pub fn build_ripple_add(
    tape: &mut impl PimTape,
    row_a: usize,
    row_b: usize,
    row_out: usize,
) {
    let w = tape.width();
    for t in [T_G, T_P, T_C, T_X] {
        tape.scratch(t);
    }
    tape.op(PimOp::And { a: row_a, b: row_b, dst: T_G });
    tape.op(PimOp::Xor { a: row_a, b: row_b, dst: T_P });
    // c = shift_up(G); then W-1 refinement rounds
    shift_in_element(tape, T_G, T_C, Dir::Up, 1, mask_row_for(1));
    for _ in 0..w.saturating_sub(1) {
        // c' = shift_up(G | (P & c))
        tape.op(PimOp::And { a: T_P, b: T_C, dst: T_X });
        tape.op(PimOp::Or { a: T_G, b: T_X, dst: T_X });
        shift_in_element(tape, T_X, T_C, Dir::Up, 1, mask_row_for(1));
    }
    tape.op(PimOp::Xor { a: T_P, b: T_C, dst: row_out });
}

/// Kogge-Stone add: `row_out := row_a + row_b` in log₂W prefix rounds.
/// Cached per shape.
pub fn kogge_stone_add(ctx: &mut ElementCtx, row_a: usize, row_b: usize, row_out: usize) {
    ctx.run_kernel(
        "adder.kogge_stone",
        &[row_a as u64, row_b as u64, row_out as u64],
        |t| build_kogge_stone_add(t, row_a, row_b, row_out),
    );
}

/// Emit the Kogge-Stone schedule onto a tape.
pub fn build_kogge_stone_add(
    tape: &mut impl PimTape,
    row_a: usize,
    row_b: usize,
    row_out: usize,
) {
    let w = tape.width();
    assert!(w.is_power_of_two(), "Kogge-Stone wants power-of-two widths");
    for t in [T_G, T_P, T_C, T_S, T_X] {
        tape.scratch(t);
    }
    tape.op(PimOp::And { a: row_a, b: row_b, dst: T_G });
    tape.op(PimOp::Xor { a: row_a, b: row_b, dst: T_P });
    // keep the half-sum: S = P (G/P get consumed by the prefix rounds)
    tape.op(PimOp::Copy { src: T_P, dst: T_S });
    let mut d = 1;
    while d < w {
        // G = G | (P & (G << d));  P = P & (P << d)
        shift_in_element(tape, T_G, T_X, Dir::Up, d, mask_row_for(d));
        tape.op(PimOp::And { a: T_P, b: T_X, dst: T_X });
        tape.op(PimOp::Or { a: T_G, b: T_X, dst: T_G });
        shift_in_element(tape, T_P, T_X, Dir::Up, d, mask_row_for(d));
        tape.op(PimOp::And { a: T_P, b: T_X, dst: T_P });
        d *= 2;
    }
    // carries into each position: c = G << 1; sum = S ^ c
    shift_in_element(tape, T_G, T_C, Dir::Up, 1, mask_row_for(1));
    tape.op(PimOp::Xor { a: T_S, b: T_C, dst: row_out });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn setup(width: usize) -> ElementCtx {
        let mut ctx = ElementCtx::new(40, 512, width);
        install_masks(&mut ctx);
        ctx
    }

    fn check_adder(width: usize, kind: &str, seed: u64) {
        let mut ctx = setup(width);
        let mut rng = Rng::new(seed);
        let n = ctx.n_elements();
        let modmask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let a: Vec<u64> = (0..n).map(|_| rng.next_u64() & modmask).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.next_u64() & modmask).collect();
        let (ra, rb) = (ctx.pack(&a), ctx.pack(&b));
        ctx.set_row(0, ra);
        ctx.set_row(1, rb);
        match kind {
            "ripple" => ripple_add(&mut ctx, 0, 1, 2),
            _ => kogge_stone_add(&mut ctx, 0, 1, 2),
        }
        let got = ctx.unpack(&ctx.row(2));
        let want: Vec<u64> = a
            .iter()
            .zip(&b)
            .map(|(x, y)| x.wrapping_add(*y) & modmask)
            .collect();
        assert_eq!(got, want, "{kind} w={width}");
    }

    #[test]
    fn ripple_8bit() {
        check_adder(8, "ripple", 1);
    }

    #[test]
    fn ripple_16bit() {
        check_adder(16, "ripple", 2);
    }

    #[test]
    fn kogge_stone_8bit() {
        check_adder(8, "ks", 3);
    }

    #[test]
    fn kogge_stone_16bit() {
        check_adder(16, "ks", 4);
    }

    #[test]
    fn kogge_stone_32bit() {
        check_adder(32, "ks", 5);
    }

    #[test]
    fn edge_values() {
        let mut ctx = setup(8);
        let n = ctx.n_elements();
        let mut a = vec![0u64; n];
        let mut b = vec![0u64; n];
        // carry chains: 0xFF+1, 0x80+0x80, 0+0, 0xFF+0xFF
        let cases = [(0xFF, 1), (0x80, 0x80), (0, 0), (0xFF, 0xFF), (0x7F, 0x01)];
        for (j, (x, y)) in cases.iter().enumerate() {
            a[j] = *x;
            b[j] = *y;
        }
        ctx.set_row(0, ctx.pack(&a));
        ctx.set_row(1, ctx.pack(&b));
        kogge_stone_add(&mut ctx, 0, 1, 2);
        let got = ctx.unpack(&ctx.row(2));
        for (j, (x, y)) in cases.iter().enumerate() {
            assert_eq!(got[j], (x + y) & 0xFF, "case {j}");
        }
    }

    #[test]
    fn kogge_stone_beats_ripple_on_aaps() {
        // the §8.0.1 question: quantify the benefit. KS does O(log W)
        // shift rounds vs ripple's O(W).
        let mut rc = setup(16);
        rc.set_row(0, rc.pack(&vec![3; rc.n_elements()]));
        rc.set_row(1, rc.pack(&vec![5; rc.n_elements()]));
        ripple_add(&mut rc, 0, 1, 2);
        let mut ks = setup(16);
        ks.set_row(0, ks.pack(&vec![3; ks.n_elements()]));
        ks.set_row(1, ks.pack(&vec![5; ks.n_elements()]));
        kogge_stone_add(&mut ks, 0, 1, 2);
        assert!(
            ks.aaps < rc.aaps,
            "KS {} AAPs should beat ripple {} at W=16",
            ks.aaps,
            rc.aaps
        );
    }

    #[test]
    fn repeated_adds_hit_the_kernel_cache() {
        use crate::config::DramConfig;
        use crate::pim::compile::ProgramCache;
        use std::sync::Arc;

        // private cache so counters aren't shared with concurrent tests
        let cache = Arc::new(ProgramCache::new(16));
        let mut ctx =
            ElementCtx::with_config(40, 512, 8, DramConfig::tiny_test(), cache.clone());
        install_masks(&mut ctx);
        let n = ctx.n_elements();
        let vals: Vec<u64> = (0..n).map(|j| j as u64 % 256).collect();
        ctx.set_row(0, ctx.pack(&vals));
        ctx.set_row(1, ctx.pack(&vals));
        kogge_stone_add(&mut ctx, 0, 1, 2);
        kogge_stone_add(&mut ctx, 0, 1, 2);
        ripple_add(&mut ctx, 0, 1, 2);
        let s = cache.stats();
        assert_eq!(s.misses, 2, "one compile per adder shape: {s:?}");
        assert_eq!(
            s.hits + s.batched,
            1,
            "repeat call served without recompiling: {s:?}"
        );
    }
}
