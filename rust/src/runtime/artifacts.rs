//! Artifact discovery and the manifest contract with python/compile/aot.py.
//!
//! The manifest is a small flat JSON object; we parse the handful of
//! integer fields with a purpose-built scanner (serde is not available in
//! the offline build) and validate them against the crate's expectations.

use std::path::{Path, PathBuf};

use crate::runtime::{Result, RuntimeError};

/// Shapes of the AOT artifacts, as written by aot.py.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub n_params: usize,
    pub n_out: usize,
    pub mc_batch: usize,
    pub mc_tile: usize,
    pub waveform_len: usize,
    pub waveform_nodes: usize,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| RuntimeError::new(format!("reading {}: {e}", path.display())))?;
        Self::parse(&text)
    }

    /// Extract `"key": <uint>` fields from a flat JSON object.
    pub fn parse(text: &str) -> Result<Self> {
        let field = |key: &str| -> Result<usize> {
            let pat = format!("\"{key}\"");
            let at = text
                .find(&pat)
                .ok_or_else(|| RuntimeError::new(format!("manifest missing field {key}")))?;
            let rest = &text[at + pat.len()..];
            let rest = rest
                .trim_start()
                .strip_prefix(':')
                .ok_or_else(|| RuntimeError::new(format!("malformed field {key}")))?
                .trim_start();
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            digits
                .parse()
                .map_err(|e| RuntimeError::new(format!("non-integer value for {key}: {e}")))
        };
        let m = Manifest {
            n_params: field("n_params")?,
            n_out: field("n_out")?,
            mc_batch: field("mc_batch")?,
            mc_tile: field("mc_tile")?,
            waveform_len: field("waveform_len")?,
            waveform_nodes: field("waveform_nodes")?,
        };
        m.validate()?;
        Ok(m)
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_params != 16 {
            return Err(RuntimeError::new(format!(
                "artifact n_params {} != crate expectation 16 — re-run `make artifacts`",
                self.n_params
            )));
        }
        if self.n_out != 6 {
            return Err(RuntimeError::new(format!("artifact n_out {} != 6", self.n_out)));
        }
        if self.mc_batch == 0 || self.mc_batch % self.mc_tile != 0 {
            return Err(RuntimeError::new(format!(
                "mc_batch {} not a multiple of tile",
                self.mc_batch
            )));
        }
        Ok(())
    }
}

/// Locate the artifacts directory: `$SHIFTDRAM_ARTIFACTS` or
/// `<repo>/artifacts` relative to the crate root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SHIFTDRAM_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "format": "hlo-text",
  "return_tuple": true,
  "n_params": 16,
  "n_out": 6,
  "mc_batch": 8192,
  "mc_tile": 512,
  "waveform_len": 72,
  "waveform_nodes": 5,
  "cfg": {"dt": 1e-10},
  "steps_per_aap": 360
}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.mc_batch, 8192);
        assert_eq!(m.mc_tile, 512);
        assert_eq!(m.waveform_len, 72);
    }

    #[test]
    fn missing_field_rejected() {
        assert!(Manifest::parse("{\"n_params\": 16}").is_err());
    }

    #[test]
    fn wrong_shapes_rejected() {
        let bad = SAMPLE.replace("\"n_params\": 16", "\"n_params\": 12");
        assert!(Manifest::parse(&bad).is_err());
        let bad = SAMPLE.replace("\"mc_batch\": 8192", "\"mc_batch\": 1000");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_file_error_names_path() {
        let err = Manifest::load(Path::new("/nonexistent/manifest.json")).unwrap_err();
        assert!(format!("{err}").contains("/nonexistent/manifest.json"));
    }

    #[test]
    fn real_manifest_if_present() {
        let p = artifacts_dir().join("manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert_eq!(m.mc_batch % m.mc_tile, 0);
        }
    }
}
