//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust.
//!
//! HLO **text** is the interchange format — jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that the crate's xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see python/compile/aot.py).
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only place the request path touches the compiled artifacts.

pub mod artifacts;

pub use artifacts::{artifacts_dir, Manifest};

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// A PJRT CPU client with a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, exes: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Load the standard artifact set (`shift_mc`, `shift_waveform`) from
    /// [`artifacts_dir`], returning the runtime and validated manifest.
    pub fn with_artifacts() -> Result<(Self, Manifest)> {
        let dir = artifacts_dir();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let mut rt = Self::new()?;
        rt.load_hlo_text("shift_mc", &dir.join("shift_mc.hlo.txt"))?;
        rt.load_hlo_text("shift_waveform", &dir.join("shift_waveform.hlo.txt"))?;
        Ok((rt, manifest))
    }

    /// Execute a single-input (f32 tensor) → single-output (f32 tensor)
    /// artifact. `dims` is the input shape; returns the flattened output
    /// (artifacts are lowered with `return_tuple=True`, so the 1-tuple is
    /// unwrapped here).
    pub fn exec_f32(&self, name: &str, input: &[f32], dims: &[i64]) -> Result<Vec<f32>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
        let lit = xla::Literal::vec1(input)
            .reshape(dims)
            .context("reshaping input literal")?;
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests require `make artifacts` to have run (they are the
    // Rust half of the AOT round trip the Python tests can't perform).
    fn runtime_with(name: &str, file: &str) -> Option<Runtime> {
        let dir = artifacts_dir();
        let path = dir.join(file);
        if !path.exists() {
            eprintln!("skipping: {} missing (run `make artifacts`)", path.display());
            return None;
        }
        let mut rt = Runtime::new().expect("PJRT CPU client");
        rt.load_hlo_text(name, &path).expect("load artifact");
        Some(rt)
    }

    #[test]
    fn loads_and_executes_mc_artifact() {
        let Some(rt) = runtime_with("mc", "shift_mc.hlo.txt") else { return };
        let m = Manifest::load(&artifacts_dir().join("manifest.json")).unwrap();
        // nominal 22 nm '1' bit in every trial
        let nominal = crate::circuit::params::TechNode::n22().mc_nominal(true);
        let mut input = Vec::with_capacity(m.mc_batch * m.n_params);
        for _ in 0..m.mc_batch {
            input.extend_from_slice(&nominal);
        }
        let out = rt
            .exec_f32("mc", &input, &[m.mc_batch as i64, m.n_params as i64])
            .unwrap();
        assert_eq!(out.len(), m.mc_batch * m.n_out);
        // all-nominal trials: full-rail write-back and positive margins
        for t in 0..m.mc_batch {
            let sense_a = out[t * m.n_out];
            let v_dst = out[t * m.n_out + 2];
            assert!(sense_a > 0.05, "trial {t} sense {sense_a}");
            assert!(v_dst > 1.1, "trial {t} v_dst {v_dst}");
        }
    }

    #[test]
    fn missing_artifact_is_reported() {
        let mut rt = Runtime::new().expect("client");
        let err = rt
            .load_hlo_text("nope", Path::new("/nonexistent/foo.hlo.txt"))
            .unwrap_err();
        assert!(format!("{err:#}").contains("foo.hlo.txt"));
        assert!(!rt.is_loaded("nope"));
        assert!(rt.exec_f32("nope", &[0.0], &[1]).is_err());
    }
}
