//! PJRT runtime bridge — offline stub.
//!
//! The original bridge loaded the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, see python/compile/aot.py) and executed them
//! through the external `xla` crate's PJRT CPU client. That crate (and
//! `anyhow`) cannot be vendored into the offline build, so this module
//! keeps the exact same API surface — [`Runtime::new`],
//! [`Runtime::with_artifacts`], [`Runtime::exec_f32`] — but every
//! execution path returns [`RuntimeError`]. All callers (the Monte-Carlo
//! harness, `main.rs`, the benches, the round-trip tests) already handle
//! that error by falling back to the native transient oracle
//! ([`crate::circuit::native`]), which is bit-compatible with the Pallas
//! kernel by construction.
//!
//! Restoring the real bridge is a dependency change only: re-add the `xla`
//! crate and swap this file for the PJRT-backed implementation; the
//! [`Manifest`] contract in [`artifacts`] is unchanged.

pub mod artifacts;

pub use artifacts::{artifacts_dir, Manifest};

use std::fmt;
use std::path::Path;

/// Error type of the runtime layer (the offline stand-in for `anyhow`).
#[derive(Clone, Debug)]
pub struct RuntimeError {
    msg: String,
}

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> Self {
        RuntimeError { msg: msg.into() }
    }

    /// Wrap with context, anyhow-style: "context: cause".
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        RuntimeError { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// A PJRT CPU client with a cache of compiled executables (stubbed: the
/// offline build cannot construct one).
pub struct Runtime {
    _unconstructible: (),
}

impl Runtime {
    /// Create the PJRT CPU client. Always fails in the offline build.
    pub fn new() -> Result<Self> {
        Err(RuntimeError::new(
            "PJRT runtime unavailable: built without the external `xla` crate \
             (offline stub) — use the native transient backend",
        ))
    }

    pub fn platform(&self) -> String {
        "offline-stub".to_string()
    }

    /// Load + compile an HLO-text artifact under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        Err(RuntimeError::new(format!(
            "cannot load artifact {name} from {}: PJRT runtime stubbed out",
            path.display()
        )))
    }

    pub fn is_loaded(&self, _name: &str) -> bool {
        false
    }

    /// Load the standard artifact set (`shift_mc`, `shift_waveform`) from
    /// [`artifacts_dir`], returning the runtime and validated manifest.
    pub fn with_artifacts() -> Result<(Self, Manifest)> {
        let dir = artifacts_dir();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let rt = Self::new()?;
        Ok((rt, manifest))
    }

    /// Execute a single-input (f32 tensor) → single-output (f32 tensor)
    /// artifact. `dims` is the input shape.
    pub fn exec_f32(&self, name: &str, _input: &[f32], _dims: &[i64]) -> Result<Vec<f32>> {
        Err(RuntimeError::new(format!(
            "cannot execute artifact {name}: PJRT runtime stubbed out"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = Runtime::new().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("PJRT"), "{msg}");
        assert!(msg.contains("native"), "points the caller at the fallback: {msg}");
    }

    #[test]
    fn with_artifacts_always_errs_offline() {
        // either the manifest is missing (usual case) or the client
        // construction fails — both must surface as Err so every caller
        // takes its native-backend fallback path
        assert!(Runtime::with_artifacts().is_err());
    }

    #[test]
    fn error_context_chains() {
        let e = RuntimeError::new("inner").context("outer");
        assert_eq!(format!("{e}"), "outer: inner");
    }
}
