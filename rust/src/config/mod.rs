//! Typed configuration system.
//!
//! Everything the simulators consume — DRAM geometry, JEDEC timing, IDD
//! energy coefficients, Monte-Carlo calibration — is a plain-data struct
//! with a validated constructor and named presets, so every experiment in
//! EXPERIMENTS.md is replayable from a preset name.

pub mod dram;
pub mod mc;

pub use dram::{DramConfig, EnergyConfig, GeometryConfig, TimingConfig};
pub use mc::McConfig;
