//! DRAM device configuration: geometry, JEDEC timing, IDD-based energy.
//!
//! The preset [`DramConfig::ddr3_1333_4gb`] models the paper's evaluation
//! target (§4.1): a Micron DDR3-1333 4 Gb chip — 8 banks/rank, 2 ranks/
//! channel, 2 channels, 512-row subarrays with 8 KB row buffers, standard
//! DDR3-1333 timing (tRCD = tRP = 13.5 ns, tRAS = 36 ns, tRC = 49.5 ns,
//! tREFI = 7.8 µs).
//!
//! All times are picoseconds (u64); all energies are picojoules (f64).

/// Array geometry / organization.
#[derive(Clone, Debug, PartialEq)]
pub struct GeometryConfig {
    pub channels: usize,
    pub ranks_per_channel: usize,
    pub banks_per_rank: usize,
    pub subarrays_per_bank: usize,
    /// data rows per subarray (excludes migration + compute rows)
    pub rows_per_subarray: usize,
    /// columns per row == bits per row buffer (8 KB row -> 65,536)
    pub cols_per_row: usize,
}

impl GeometryConfig {
    pub fn total_banks(&self) -> usize {
        self.channels * self.ranks_per_channel * self.banks_per_rank
    }

    pub fn row_bytes(&self) -> usize {
        self.cols_per_row / 8
    }

    /// per-chip capacity in bits (8 banks × subarrays × rows × cols for
    /// the 4 Gb part; the system spans `total_banks()` across ranks and
    /// channels)
    pub fn chip_capacity_bits(&self) -> usize {
        self.banks_per_rank * self.subarrays_per_bank * self.rows_per_subarray * self.cols_per_row
    }
}

/// JEDEC timing parameters, picoseconds.
#[derive(Clone, Debug, PartialEq)]
pub struct TimingConfig {
    pub t_ck: u64,
    pub t_rcd: u64,
    pub t_rp: u64,
    pub t_ras: u64,
    pub t_rc: u64,
    pub t_rrd: u64,
    pub t_faw: u64,
    pub t_wr: u64,
    pub t_cas: u64,
    /// BL8 data burst duration
    pub t_burst: u64,
    pub t_refi: u64,
    pub t_rfc: u64,
    /// extra issue latency of the second ACT inside an AAP sequence
    /// (Ambit's back-to-back row decode; calibration: 2 tCK)
    pub t_aap_extra: u64,
}

impl TimingConfig {
    /// Latency of one AAP (ACT-ACT-PRE) command sequence.
    ///
    /// Ambit reports ~49 ns for AAP on DDR3-1333 (tRAS + tRP = 49.5 ns);
    /// we add `t_aap_extra` for the second ACT's row decode. With the
    /// DDR3-1333 preset this is 52.5 ns, so a 4-AAP shift is 210 ns —
    /// within 0.6 % of the paper's measured 208.7 ns single shift.
    pub fn t_aap(&self) -> u64 {
        self.t_ras + self.t_rp + self.t_aap_extra
    }
}

/// IDD current draws (mA) and derived per-command energies.
///
/// Energy formulas follow NVMain/Micron practice:
///   E(ACT+PRE cycle) = (IDD0·tRC − (IDD3N·tRAS + IDD2N·(tRC−tRAS)))·VDD
///   E(REF)           = (IDD5 − IDD3N)·VDD·tRFC
///   E(burst, 64 B)   = e_burst_64b (I/O + DLL, used by the CPU baseline)
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyConfig {
    pub vdd: f64,
    pub idd0_ma: f64,
    pub idd2n_ma: f64,
    pub idd3n_ma: f64,
    pub idd5_ma: f64,
    /// precharge bookkeeping energy per PRE, pJ (bitline equalization)
    pub e_pre_pj: f64,
    /// off-chip transfer energy per 64-byte burst, pJ (§5.1.5 uses
    /// 10–15 nJ per 64 B for DDR3; we take the midpoint)
    pub e_burst_64b_pj: f64,
}

impl EnergyConfig {
    /// Energy of one row activation (charge share + sense + restore), pJ.
    pub fn e_act_pj(&self, t: &TimingConfig) -> f64 {
        let idd0 = self.idd0_ma * 1e-3;
        let idd2n = self.idd2n_ma * 1e-3;
        let idd3n = self.idd3n_ma * 1e-3;
        let t_rc = t.t_rc as f64 * 1e-12;
        let t_ras = t.t_ras as f64 * 1e-12;
        let e = (idd0 * t_rc - (idd3n * t_ras + idd2n * (t_rc - t_ras))) * self.vdd;
        e * 1e12
    }

    /// Energy of one refresh command, pJ.
    pub fn e_ref_pj(&self, t: &TimingConfig) -> f64 {
        let i = (self.idd5_ma - self.idd3n_ma) * 1e-3;
        i * self.vdd * (t.t_rfc as f64 * 1e-12) * 1e12
    }

    /// Background (standby) power, W — reported separately; the paper's
    /// Table 2 scopes energy to Bank 0 Subarray 0 and excludes standby.
    pub fn standby_w(&self) -> f64 {
        self.idd3n_ma * 1e-3 * self.vdd
    }
}

/// Full device configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct DramConfig {
    pub name: String,
    pub geometry: GeometryConfig,
    pub timing: TimingConfig,
    pub energy: EnergyConfig,
}

impl DramConfig {
    /// The paper's evaluation configuration (§4.1).
    ///
    /// IDD values are calibrated so that E(ACT) = 3.78 nJ, making a 4-AAP
    /// (8-ACT) shift cost 30.24 nJ of active energy — the paper's Table 2
    /// value — while staying inside the datasheet range for a DDR3-1333
    /// 4 Gb part (IDD0 ≈ 85–100 mA).
    pub fn ddr3_1333_4gb() -> Self {
        let cfg = DramConfig {
            name: "ddr3-1333-4gb".into(),
            geometry: GeometryConfig {
                channels: 2,
                ranks_per_channel: 2,
                banks_per_rank: 8,
                subarrays_per_bank: 16,
                rows_per_subarray: 512,
                cols_per_row: 65_536,
            },
            timing: TimingConfig {
                t_ck: 1_500,
                t_rcd: 13_500,
                t_rp: 13_500,
                t_ras: 36_000,
                t_rc: 49_500,
                t_rrd: 6_000,
                t_faw: 30_000,
                t_wr: 15_000,
                t_cas: 13_500,
                t_burst: 6_000,
                t_refi: 7_800_000,
                t_rfc: 260_000,
                t_aap_extra: 3_000,
            },
            energy: EnergyConfig {
                vdd: 1.5,
                idd0_ma: 95.1,
                idd2n_ma: 42.0,
                idd3n_ma: 45.0,
                idd5_ma: 242.7,
                e_pre_pj: 270.25,
                e_burst_64b_pj: 12_500.0,
            },
        };
        cfg.validate().expect("preset must validate");
        cfg
    }

    /// This config shrunk to a private 1-channel/1-rank/1-bank geometry of
    /// a single `rows × cols` subarray, keeping the timing and energy
    /// models. The app layer's private systems ([`crate::apps`]'s
    /// `ElementCtx`) derive their geometry from this one constructor, so
    /// geometry edits cannot silently diverge from the shared definition.
    pub fn single_channel(&self, rows_per_subarray: usize, cols_per_row: usize) -> Self {
        let mut cfg = self.clone();
        cfg.geometry = GeometryConfig {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 1,
            subarrays_per_bank: 1,
            rows_per_subarray,
            cols_per_row,
        };
        cfg
    }

    /// A small config for fast functional tests (256-column rows).
    pub fn tiny_test() -> Self {
        let mut cfg = Self::ddr3_1333_4gb();
        cfg.name = "tiny-test".into();
        cfg.geometry.cols_per_row = 256;
        cfg.geometry.rows_per_subarray = 32;
        cfg.geometry.subarrays_per_bank = 2;
        cfg
    }

    pub fn validate(&self) -> Result<(), String> {
        let g = &self.geometry;
        let t = &self.timing;
        if g.cols_per_row == 0 || g.cols_per_row % 2 != 0 {
            return Err("cols_per_row must be a positive even number \
                        (migration cells pair adjacent bitlines)"
                .into());
        }
        if g.rows_per_subarray < 4 {
            return Err("need at least 4 data rows".into());
        }
        if t.t_rc < t.t_ras + t.t_rp {
            return Err("tRC must cover tRAS + tRP".into());
        }
        if t.t_ras < t.t_rcd {
            return Err("tRAS must cover tRCD".into());
        }
        if t.t_refi == 0 || t.t_rfc == 0 {
            return Err("refresh timing must be nonzero".into());
        }
        if self.energy.e_act_pj(t) <= 0.0 {
            return Err("IDD configuration yields non-positive ACT energy".into());
        }
        Ok(())
    }

    /// Per-shift command cost: 4 AAPs (paper §3.3).
    pub fn aaps_per_shift(&self) -> u64 {
        4
    }

    /// Stable 64-bit fingerprint of every cost-relevant field (geometry,
    /// timing, energy — the `name` label is excluded). Two configs with
    /// the same fingerprint price identical command streams identically,
    /// which is what keys the compile layer's `ProgramCache` and guards
    /// `BankSim::run_compiled` against cross-config program reuse.
    pub fn fingerprint(&self) -> u64 {
        // FNV-1a over the canonical Debug rendering of the plain-data
        // sub-structs (deterministic field order and float formatting).
        let text = format!("{:?}|{:?}|{:?}", self.geometry, self.timing, self.energy);
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in text.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_validates() {
        DramConfig::ddr3_1333_4gb().validate().unwrap();
        DramConfig::tiny_test().validate().unwrap();
    }

    #[test]
    fn preset_matches_paper_parameters() {
        let c = DramConfig::ddr3_1333_4gb();
        assert_eq!(c.geometry.row_bytes(), 8192);
        assert_eq!(c.geometry.total_banks(), 32);
        assert_eq!(c.timing.t_rcd, 13_500);
        assert_eq!(c.timing.t_rp, 13_500);
        assert_eq!(c.timing.t_ras, 36_000);
        assert_eq!(c.timing.t_rc, 49_500);
        assert_eq!(c.timing.t_refi, 7_800_000);
        // 4 Gb chip capacity
        assert_eq!(c.geometry.chip_capacity_bits(), 4 * 1024 * 1024 * 1024usize);
    }

    #[test]
    fn act_energy_calibration() {
        // §6 of DESIGN.md: E(ACT) = 3.78 nJ ± 0.3 %
        let c = DramConfig::ddr3_1333_4gb();
        let e = c.energy.e_act_pj(&c.timing);
        assert!((e - 3_780.0).abs() < 12.0, "E(ACT) = {e} pJ");
    }

    #[test]
    fn ref_energy_calibration() {
        // Table 2: one refresh event ≈ 77.1 nJ
        let c = DramConfig::ddr3_1333_4gb();
        let e = c.energy.e_ref_pj(&c.timing);
        assert!((e - 77_117.0).abs() < 200.0, "E(REF) = {e} pJ");
    }

    #[test]
    fn aap_latency_near_paper() {
        // single shift = 4 AAP ≈ 208.7 ns in the paper; we model 210 ns
        let c = DramConfig::ddr3_1333_4gb();
        let shift_ps = 4 * c.timing.t_aap();
        assert_eq!(shift_ps, 210_000);
        let rel = (shift_ps as f64 - 208_700.0).abs() / 208_700.0;
        assert!(rel < 0.01, "within 1% of paper");
    }

    #[test]
    fn fingerprint_tracks_cost_fields_only() {
        let base = DramConfig::ddr3_1333_4gb();
        assert_eq!(base.fingerprint(), DramConfig::ddr3_1333_4gb().fingerprint());

        let mut renamed = base.clone();
        renamed.name = "other-label".into();
        assert_eq!(base.fingerprint(), renamed.fingerprint(), "name is a label");

        let mut slower = base.clone();
        slower.timing.t_aap_extra += 1;
        assert_ne!(base.fingerprint(), slower.fingerprint());

        let mut smaller = base.clone();
        smaller.geometry.cols_per_row = 256;
        assert_ne!(base.fingerprint(), smaller.fingerprint());

        assert_ne!(base.fingerprint(), DramConfig::tiny_test().fingerprint());
    }

    #[test]
    fn single_channel_keeps_pricing_and_shrinks_geometry() {
        let base = DramConfig::ddr3_1333_4gb();
        let small = base.single_channel(24, 256);
        assert_eq!(small.geometry.channels, 1);
        assert_eq!(small.geometry.ranks_per_channel, 1);
        assert_eq!(small.geometry.banks_per_rank, 1);
        assert_eq!(small.geometry.subarrays_per_bank, 1);
        assert_eq!(small.geometry.rows_per_subarray, 24);
        assert_eq!(small.geometry.cols_per_row, 256);
        assert_eq!(small.geometry.total_banks(), 1);
        assert_eq!(small.timing, base.timing, "pricing models are preserved");
        assert_eq!(small.energy, base.energy);
        small.validate().unwrap();
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = DramConfig::ddr3_1333_4gb();
        c.geometry.cols_per_row = 65_537;
        assert!(c.validate().is_err());

        let mut c = DramConfig::ddr3_1333_4gb();
        c.timing.t_rc = 10_000;
        assert!(c.validate().is_err());

        let mut c = DramConfig::ddr3_1333_4gb();
        c.timing.t_refi = 0;
        assert!(c.validate().is_err());
    }
}
