//! Monte-Carlo calibration for the circuit-level reliability study
//! (paper §4.2 / §5.2, Table 4).
//!
//! The paper sweeps process variation from ±0 % to ±20 % with 100,000
//! LTSPICE transient simulations per level, perturbing cell capacitance,
//! transistor L/W (→ on-resistance), and bitline/wordline parasitics.
//! We reproduce the same protocol against the AOT-compiled JAX/Pallas
//! transient kernel. A "±X %" level draws each physical parameter as
//! `nominal · (1 + N(0, X/100))` and an input-referred sense-amp offset as
//! `N(0, sa_offset_frac · (X/100) · VDD)` (SA offset is a mismatch effect
//! and scales with the variation level; at ±0 % the circuit is noiseless
//! and must never fail — Table 4's 0.00 % row).

/// Monte-Carlo protocol configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct McConfig {
    /// trials per variation level (paper: 100,000)
    pub trials: usize,
    /// variation levels as fractions (paper: 0, 0.05, 0.10, 0.20)
    pub levels: Vec<f64>,
    /// σ of the SA input-referred offset, as a fraction of VDD per unit of
    /// variation level (calibrated so ±5 % → ≈0.5 % failures, Table 4)
    pub sa_offset_frac: f64,
    /// saturation of the offset σ (fraction of VDD): device sizing bounds
    /// the mismatch at extreme variation, which is what bends Table 4's
    /// curve from ~14 % at ±10 % to only ~30 % at ±20 %
    pub sa_offset_cap: f64,
    /// retention droop applied to a stored '1' before the shift, as a
    /// fraction of VDD (worst-case cell at the end of its refresh window)
    pub retention_droop: f64,
    /// read-margin threshold (V): a trial fails if either AAP's sense
    /// margin falls below this, or the final cell level is degraded
    pub margin_threshold_v: f64,
    /// final-level criterion: |V_dst − rail| must be within this fraction
    /// of VDD (paper §4.2 "complete write-back")
    pub writeback_frac: f64,
    /// RNG seed for the parameter draws
    pub seed: u64,
}

impl Default for McConfig {
    fn default() -> Self {
        Self::paper()
    }
}

impl McConfig {
    /// The paper's protocol: 100 k trials at ±0/5/10/20 %.
    pub fn paper() -> Self {
        McConfig {
            trials: 100_000,
            levels: vec![0.0, 0.05, 0.10, 0.20],
            sa_offset_frac: 0.50,
            sa_offset_cap: 0.07,
            retention_droop: 0.08,
            margin_threshold_v: 0.0,
            writeback_frac: 0.25,
            seed: 0xD2A_2026,
        }
    }

    /// A fast variant for tests/CI (same levels, fewer trials).
    pub fn quick() -> Self {
        McConfig { trials: 8_192, ..Self::paper() }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.trials == 0 {
            return Err("trials must be positive".into());
        }
        if self.levels.iter().any(|&l| !(0.0..=1.0).contains(&l)) {
            return Err("variation levels must be fractions in [0,1]".into());
        }
        if !(0.0..=0.5).contains(&self.retention_droop) {
            return Err("retention droop out of range".into());
        }
        if !(0.0..1.0).contains(&self.writeback_frac) {
            return Err("writeback fraction out of range".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        McConfig::paper().validate().unwrap();
        McConfig::quick().validate().unwrap();
    }

    #[test]
    fn paper_protocol_matches_table4() {
        let c = McConfig::paper();
        assert_eq!(c.trials, 100_000);
        assert_eq!(c.levels, vec![0.0, 0.05, 0.10, 0.20]);
    }

    #[test]
    fn bad_configs_rejected() {
        let mut c = McConfig::paper();
        c.trials = 0;
        assert!(c.validate().is_err());
        let mut c = McConfig::paper();
        c.levels = vec![1.5];
        assert!(c.validate().is_err());
    }
}
