//! Report printers: regenerate every table and figure of the paper in the
//! paper's own row/column format, with a paper-vs-measured column where
//! the numbers are simulated.

use crate::baselines::{CpuMovement, Drisa, MigrationShift, ShiftApproach, Simdram};
use crate::circuit::montecarlo::{Backend, MonteCarlo};
use crate::circuit::params::TechNode;
use crate::circuit::validation::validate_all_nodes;
use crate::config::{DramConfig, McConfig};
use crate::layout::geometry::{check_drc, LayoutRules, MigrationCellLayout, MimCap};
use crate::sim::workload::{run_paper_workloads, PAPER_WORKLOADS};

fn hr(width: usize) {
    println!("{}", "-".repeat(width));
}

/// Table 1: DRAM cell and circuit parameters across technology nodes.
pub fn table1() {
    println!("Table 1: DRAM cell and circuit parameters across technology nodes");
    hr(100);
    println!(
        "{:<12}{:>12}{:>12}{:>12}{:>12}{:>12}{:>12}",
        "Parameter", "600nm", "180nm", "45nm", "22nm", "20nm", "10nm"
    );
    hr(100);
    let nodes = TechNode::all();
    let row = |name: &str, f: &dyn Fn(&TechNode) -> String| {
        print!("{name:<12}");
        for n in &nodes {
            print!("{:>12}", f(n));
        }
        println!();
    };
    row("Vdd", &|n| format!("{:.1}V", n.vdd));
    row("WL boost", &|n| format!("{:.1}V", n.wl_boost));
    row("Cell Cap", &|n| format!("{:.0}fF", n.c_cell * 1e15));
    row("Access L", &|n| format!("{:.3}u", n.access_l * 1e6));
    row("Access W", &|n| format!("{:.3}u", n.access_w * 1e6));
    row("SA NMOS W", &|n| format!("{:.1}u", n.sa_nmos_w * 1e6));
    row("BL R/cell", &|n| format!("{:.0}m", n.bl_r_per_cell * 1e3));
    row("BL C/cell", &|n| format!("{:.2}f", n.bl_c_per_cell * 1e15));
    row("trise", &|n| format!("{:.1}n", n.t_rise * 1e9));
    row("R_on (der.)", &|n| format!("{:.0}k", n.r_on / 1e3));
    hr(100);
}

/// Tables 2 + 3: energy breakdown and performance of the shift workloads.
pub fn table2_and_3(cfg: &DramConfig, seed: u64) {
    let reports = run_paper_workloads(cfg, seed);
    println!("Table 2: Energy Breakdown For Shift Operations (Bank 0 Subarray 0)");
    hr(86);
    println!(
        "{:<18}{:>16}{:>16}{:>16}{:>16}",
        "", "Single Shift", "50 Shifts", "100 Shifts", "512 Shifts"
    );
    hr(86);
    let row = |name: &str, f: &dyn Fn(&crate::sim::ShiftWorkloadReport) -> String| {
        print!("{name:<18}");
        for r in &reports {
            print!("{:>16}", f(r));
        }
        println!();
    };
    row("Total Energy", &|r| format!("{:.3} nJ", r.total_energy_nj()));
    row("Active Energy", &|r| format!("{:.2} nJ", r.energy.active_pj / 1e3));
    row("Burst Energy", &|r| format!("{:.0} nJ", r.energy.burst_pj / 1e3));
    row("Refresh Energy", &|r| format!("{:.2} nJ", r.energy.refresh_pj / 1e3));
    row("Precharge Energy", &|r| format!("{:.2} nJ", r.energy.precharge_pj / 1e3));
    row("Energy Per Shift", &|r| format!("{:.3} nJ", r.energy_per_shift_nj()));
    row("(verified)", &|r| format!("{}", r.verified));
    hr(86);
    println!("paper:   31.321 / 1592.52 / 3223.6 / 16554.6 nJ total; 31.3-32.3 nJ/shift");
    println!();

    println!("Table 3: Performance Characteristics (Bank 0)");
    hr(86);
    println!(
        "{:<22}{:>14}{:>14}{:>14}{:>14}",
        "Metric", "Single", "50", "100", "512"
    );
    hr(86);
    row("Total Time", &|r| {
        if r.total_time_ps < 1_000_000 {
            format!("{:.1} ns", r.total_time_ps as f64 / 1e3)
        } else {
            format!("{:.3} us", r.total_time_us())
        }
    });
    row("Latency/Shift", &|r| format!("{:.1} ns", r.latency_per_shift_ns()));
    row("Thpt (MOps/s)", &|r| format!("{:.2}", r.throughput_mops()));
    row("nJ/KB", &|r| format!("{:.3}", r.nj_per_kb(cfg.geometry.row_bytes())));
    hr(86);
    println!("paper:   208.7 ns single; 205.8-207.6 ns/shift; ~4.82 MOps/s; ~4 nJ/KB");
    println!("note:    refresh shares: {}",
        reports
            .iter()
            .map(|r| format!("{:.1}%", 100.0 * r.energy.refresh_pj / r.energy.total_pj()))
            .collect::<Vec<_>>()
            .join(" / "));
    let _ = PAPER_WORKLOADS;
}

/// Table 4: Monte-Carlo failure rate vs process variation.
pub fn table4(mc: &MonteCarlo, backend: &Backend) {
    println!(
        "Table 4: Effect of Process Variation on Shift ({} trials/level, {}, backend: {})",
        mc.mc.trials,
        mc.node.name,
        match backend {
            Backend::Native => "native",
            Backend::Pjrt(..) => "PJRT (JAX/Pallas artifact)",
        }
    );
    hr(72);
    println!("{:<12}{:>12}{:>12}{:>18}", "Variation", "%Failures", "paper", "95% CI");
    hr(72);
    let paper = [0.0, 0.5, 14.0, 30.0];
    for (i, r) in mc.run(backend).iter().enumerate() {
        let (lo, hi) = r.ci95();
        println!(
            "{:<12}{:>11.2}%{:>11.1}%{:>9.2}-{:.2}%",
            format!("±{:.0}%", r.level * 100.0),
            100.0 * r.failure_rate(),
            paper.get(i).copied().unwrap_or(f64::NAN),
            100.0 * lo,
            100.0 * hi,
        );
    }
    hr(72);
}

/// Table 5: area overhead of PIM architectures.
pub fn table5(cfg: &DramConfig) {
    println!("Table 5: Area Overhead of PIM Architectures");
    hr(96);
    println!(
        "{:<26}{:<40}{:>14}{:>14}",
        "Design", "Added Circuitry", "Overhead", "(model)"
    );
    hr(96);
    for r in crate::layout::table5(&cfg.geometry) {
        println!(
            "{:<26}{:<40}{:>14}{:>13.2}%",
            r.design, r.added_circuitry, r.reported, r.overhead_pct
        );
    }
    hr(96);
    println!(
        "ours stacked on Ambit: {:.2}% (paper: ~1-2%)",
        100.0 * crate::layout::migration_plus_ambit_overhead(&cfg.geometry)
    );
}

/// §5.1.5 / §5.1.6 comparison table.
pub fn baseline_comparison(cfg: &DramConfig) {
    let row_bytes = cfg.geometry.row_bytes();
    let ours = MigrationShift::from_config(cfg);
    let ours_nj = ours.shift_cost(row_bytes).energy_nj;
    println!("§5.1.5/§5.1.6: shift-approach comparison (8 KB row, 1-bit shift)");
    hr(108);
    println!(
        "{:<36}{:>12}{:>12}{:>14}{:>12}{:>10}{:>10}",
        "Design", "nJ/shift", "ns/shift", "setup nJ", "nJ/KB", "area %", "transp."
    );
    hr(108);
    let print_row = |a: &dyn ShiftApproach| {
        let c = a.shift_cost(row_bytes);
        println!(
            "{:<36}{:>12.2}{:>12.1}{:>14.1}{:>12.3}{:>10.2}{:>10}",
            a.name(),
            c.energy_nj,
            c.latency_ns,
            c.setup_energy_nj,
            c.energy_nj / (row_bytes as f64 / 1024.0),
            100.0 * a.area_overhead(),
            if a.needs_transposition() { "yes" } else { "no" }
        );
    };
    print_row(&ours);
    print_row(&CpuMovement::default());
    print_row(&Simdram::default());
    for d in Drisa::all_variants() {
        print_row(&d);
    }
    hr(108);
    let cpu = CpuMovement::default();
    println!(
        "vs CPU movement: read-leg ratio {:.0}x (paper: 40-60x across 10-15 nJ/64B), \
         round-trip ratio {:.0}x",
        cpu.read_energy_nj(row_bytes) / ours_nj,
        cpu.roundtrip_energy_nj(row_bytes) / ours_nj
    );
    let sd = Simdram::default();
    println!(
        "vs SIMDRAM: transposition alone = {:.0}x our full shift (paper: 100-300x)",
        sd.transpose_energy_nj(row_bytes) / ours_nj
    );
}

/// Figure 2/3 narrative: why one migration row fails and the 4-AAP flow.
pub fn fig2_fig3() {
    use crate::dram::address::{Port, RowRef};
    use crate::dram::subarray::Subarray;
    use crate::util::{BitRow, Rng, ShiftDir};
    println!("Figure 2/3: one- vs two-migration-row shift (64-column demo)");
    let mut rng = Rng::new(2);
    let row = BitRow::random(64, &mut rng);
    let want = row.shifted(ShiftDir::Right, false);

    let mut sa1 = Subarray::new(4, 64);
    sa1.write_row(0, row.clone());
    sa1.aap(RowRef::Zero, RowRef::Data(1));
    sa1.aap(RowRef::Data(0), RowRef::MigTop(Port::A));
    sa1.aap(RowRef::MigTop(Port::B), RowRef::Data(1));
    let got1 = sa1.read_row(1);
    let bad = (0..64).filter(|&i| got1.get(i) != want.get(i)).count();
    println!("  one row (Fig 2):  {bad}/64 columns wrong — even columns never move");

    let mut sa2 = Subarray::new(4, 64);
    sa2.write_row(0, row.clone());
    for c in crate::pim::shift_commands(RowRef::Data(0), RowRef::Data(1), ShiftDir::Right) {
        crate::pim::apply(&mut sa2, &c);
    }
    let ok = sa2.read_row(1) == &want;
    println!("  two rows (Fig 3): 4 AAPs, correct = {ok}");
}

/// Figure 4 / §6: computed migration-cell layout geometry.
pub fn fig4() {
    println!("Figure 4 / §6: migration-cell VLSI geometry at 22 nm");
    let layout = MigrationCellLayout::new(LayoutRules::n22(), 25e-15);
    let mim = MimCap::paper_22nm();
    println!(
        "  6F² cell: {:.0} x {:.0} nm  (access W/L = {:.0}/{:.0} nm)",
        2.0 * layout.rules.feature * 1e9,
        3.0 * layout.rules.feature * 1e9,
        layout.rules.access_wl().0 * 1e9,
        layout.rules.access_wl().1 * 1e9,
    );
    println!(
        "  MIM cap: {:.0} fF -> plate area {:.4e} nm², side {:.0} nm (paper: 1.129e6 nm², 1063 nm)",
        mim.capacitance * 1e15,
        mim.plate_area * 1e18,
        mim.plate_side * 1e9
    );
    println!(
        "  strap: {:.0} nm x {:.0} nm of metal joining the two top plates",
        layout.strap_len * 1e9,
        layout.strap_w * 1e9
    );
    let drc = check_drc(&layout);
    println!("  DRC: {}", if drc.clean() { "clean".to_string() } else { format!("{:?}", drc.violations) });
}

/// §4.2 validation matrix.
pub fn validation_matrix() {
    println!("§4.2 circuit validation matrix (native transient engine):");
    hr(86);
    println!(
        "{:<8}{:>5}{:>10}{:>10}{:>10}{:>11}{:>10}{:>10}",
        "node", "bit", "transfer", "shift", "preserve", "integrity", "charge", "wrback"
    );
    hr(86);
    for r in validate_all_nodes() {
        println!(
            "{:<8}{:>5}{:>10}{:>10}{:>10}{:>11}{:>10}{:>10}",
            r.node,
            r.bit as u8,
            r.data_transfer,
            r.correct_shift,
            r.preservation,
            r.signal_integrity,
            r.charge_transfer,
            r.writeback
        );
    }
    hr(86);
}

/// Everything (native MC backend with reduced trials unless `full`).
pub fn all(full: bool) {
    let cfg = DramConfig::ddr3_1333_4gb();
    table1();
    println!();
    table2_and_3(&cfg, 42);
    println!();
    let mc_cfg = if full { McConfig::paper() } else { McConfig::quick() };
    let mc = MonteCarlo::new(mc_cfg, TechNode::n22());
    table4(&mc, &Backend::Native);
    println!();
    table5(&cfg);
    println!();
    baseline_comparison(&cfg);
    println!();
    fig2_fig3();
    println!();
    fig4();
    println!();
    validation_matrix();
}
