//! Native Rust transient integrator — the same lumped-RC physics as the
//! JAX/Pallas kernel (python/compile/kernels/bitline.py), re-implemented
//! independently in f32.
//!
//! Purposes:
//! 1. cross-language validation — `rust/tests/runtime_roundtrip.rs` checks
//!    PJRT-executed artifact outputs against this oracle;
//! 2. fallback when artifacts are absent (unit tests, cold checkouts);
//! 3. the single-trial waveform probe used by the §4.2 validation checks.
//!
//! The AAP window model: wordline-1 conductance ramps from t = 0 over
//! `t_rise`; the latch-type SA enables at `t_sense` and regenerates about
//! the offset-shifted metastable point, rail-clamped; wordline-2 (the AAP's
//! second ACT) ramps from `t_act2`; integration is explicit Euler with
//! `dt`, over two windows (src→migration on bitline A, then migration→dst
//! on bitline B) with an inter-window precharge.

use crate::circuit::params::pidx::*;

/// Integration configuration — must mirror kernels/common.py DEFAULT_CFG.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransientCfg {
    pub dt: f32,
    pub t_sense: f32,
    pub t_act2: f32,
    pub t_end: f32,
}

impl Default for TransientCfg {
    fn default() -> Self {
        TransientCfg { dt: 0.1e-9, t_sense: 8.0e-9, t_act2: 20.0e-9, t_end: 36.0e-9 }
    }
}

impl TransientCfg {
    pub fn steps_per_aap(&self) -> usize {
        (self.t_end / self.dt).round() as usize
    }

    pub fn sense_step(&self) -> usize {
        (self.t_sense / self.dt).round() as usize
    }

    pub fn act2_step(&self) -> usize {
        (self.t_act2 / self.dt).round() as usize
    }
}

#[inline]
fn ramp(t: f32, t_rise: f32) -> f32 {
    (t / t_rise.max(1e-12)).clamp(0.0, 1.0)
}

/// One AAP window. Returns (v_first, v_second, v_bl, sense_raw).
#[allow(clippy::too_many_arguments)]
fn window(
    cfg: &TransientCfg,
    mut v1: f32,
    c1: f32,
    r1: f32,
    mut v2: f32,
    c2: f32,
    r2: f32,
    mut vb: f32,
    c_bl: f32,
    off: f32,
    vdd: f32,
    t_rise: f32,
    sa_gain: f32,
    mut trace: Option<&mut Vec<(f32, f32, f32)>>,
) -> (f32, f32, f32, f32) {
    let n = cfg.steps_per_aap();
    let k_sense = cfg.sense_step();
    let t_act2 = cfg.t_act2;
    let half = 0.5 * vdd;
    let dt = cfg.dt;
    let mut sense = 0.0f32;
    for i in 0..n {
        let t = i as f32 * dt;
        let g1 = ramp(t, t_rise) / r1;
        let g2 = ramp(t - t_act2, t_rise) / r2;
        let i1 = g1 * (vb - v1);
        let i2 = g2 * (vb - v2);
        let raw = vb - half - off;
        let i_sa = if i >= k_sense { sa_gain * raw * c_bl } else { 0.0 };
        if i == k_sense {
            sense = raw;
        }
        v1 += dt * i1 / c1;
        v2 += dt * i2 / c2;
        vb = (vb + dt * (-(i1 + i2) + i_sa) / c_bl).clamp(0.0, vdd);
        if let Some(tr) = trace.as_deref_mut() {
            tr.push((v1, v2, vb));
        }
    }
    (v1, v2, vb, sense)
}

/// Simulate one trial (16-float parameter vector → 6-float output vector).
/// Identical semantics to the Pallas kernel.
pub fn shift_transient(p: &[f32; N_PARAMS], cfg: &TransientCfg) -> [f32; N_OUT] {
    let vdd = p[VDD];
    let half = 0.5 * vdd;

    // AAP 1: src -> migration (port A) on bitline A
    let (v_src, v_mig, _bla, sense_a) = window(
        cfg, p[V_SRC0], p[C_SRC], p[R_SRC], half, p[C_MIG], p[R_MIG_A], half,
        p[C_BLA], p[OFF_A], vdd, p[T_RISE], p[SA_GAIN], None,
    );
    // AAP 2: migration (port B) -> dst on bitline B
    let (v_mig, v_dst, v_blb, sense_b) = window(
        cfg, v_mig, p[C_MIG], p[R_MIG_B], p[V_DST0], p[C_DST], p[R_DST], half,
        p[C_BLB], p[OFF_B], vdd, p[T_RISE], p[SA_GAIN], None,
    );

    [sense_a, sense_b, v_dst, v_mig, v_src, v_blb]
}

/// Full waveform of one trial: per-step (v_src, v_mig, v_dst, v_bl_a,
/// v_bl_b) across both AAP windows (matches the shift_waveform artifact's
/// node order before stride subsampling).
pub fn shift_waveform(p: &[f32; N_PARAMS], cfg: &TransientCfg) -> Vec<[f32; 5]> {
    let vdd = p[VDD];
    let half = 0.5 * vdd;
    let mut tr1 = Vec::new();
    let (v_src, v_mig, _bla, _) = window(
        cfg, p[V_SRC0], p[C_SRC], p[R_SRC], half, p[C_MIG], p[R_MIG_A], half,
        p[C_BLA], p[OFF_A], vdd, p[T_RISE], p[SA_GAIN], Some(&mut tr1),
    );
    let mut tr2 = Vec::new();
    let (_v_mig2, _v_dst, _blb, _) = window(
        cfg, v_mig, p[C_MIG], p[R_MIG_B], p[V_DST0], p[C_DST], p[R_DST], half,
        p[C_BLB], p[OFF_B], vdd, p[T_RISE], p[SA_GAIN], Some(&mut tr2),
    );
    let mut out = Vec::with_capacity(tr1.len() + tr2.len());
    for (v1, v2, vb) in tr1 {
        // window 1: first = src, second = mig, bl = A; dst untouched
        out.push([v1, v2, p[V_DST0], vb, half]);
    }
    let last_bla = out.last().map(|s| s[3]).unwrap_or(half);
    let _ = last_bla;
    for (v1, v2, vb) in tr2 {
        // window 2: first = mig, second = dst, bl = B; src settled
        out.push([v_src, v1, v2, half, vb]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::params::TechNode;

    #[test]
    fn nominal_bit1_propagates() {
        let p = TechNode::n22().mc_nominal(true);
        let out = shift_transient(&p, &TransientCfg::default());
        assert!(out[SENSE_A] > 0.05, "sense A {}", out[SENSE_A]);
        assert!(out[SENSE_B] > 0.05);
        assert!(out[V_DST_F] > 1.1, "v_dst {}", out[V_DST_F]);
        assert!(out[V_SRC_F] > 1.1, "source restored");
    }

    #[test]
    fn nominal_bit0_propagates() {
        let p = TechNode::n22().mc_nominal(false);
        let out = shift_transient(&p, &TransientCfg::default());
        assert!(out[SENSE_A] < -0.05);
        assert!(out[V_DST_F] < 0.05);
    }

    #[test]
    fn all_validated_nodes_shift_correctly() {
        // §4.2: 45/22/20/10 nm, both polarities
        for node in TechNode::validated() {
            for bit in [false, true] {
                let p = node.mc_nominal(bit);
                let out = shift_transient(&p, &TransientCfg::default());
                let vdd = node.vdd as f32;
                if bit {
                    assert!(out[V_DST_F] > 0.9 * vdd, "{} bit1", node.name);
                } else {
                    assert!(out[V_DST_F] < 0.1 * vdd, "{} bit0", node.name);
                }
            }
        }
    }

    #[test]
    fn excessive_offset_flips_the_read() {
        let mut p = TechNode::n22().mc_nominal(true);
        p[OFF_A] = 0.2; // >> ~90 mV charge-share margin
        let out = shift_transient(&p, &TransientCfg::default());
        assert!(out[SENSE_A] < 0.0);
        assert!(out[V_DST_F] < 0.1);
    }

    #[test]
    fn margin_matches_first_order_estimate() {
        let node = TechNode::n22();
        let p = node.mc_nominal(true);
        let out = shift_transient(&p, &TransientCfg::default());
        let est = node.charge_share_margin(512) as f32;
        // transient margin within 25 % of the analytic ΔV
        assert!(
            (out[SENSE_A] - est).abs() / est < 0.25,
            "sense {} vs estimate {est}",
            out[SENSE_A]
        );
    }

    #[test]
    fn waveform_length_and_story() {
        let cfg = TransientCfg::default();
        let p = TechNode::n22().mc_nominal(true);
        let wf = shift_waveform(&p, &cfg);
        assert_eq!(wf.len(), 2 * cfg.steps_per_aap());
        let mid = wf[cfg.steps_per_aap() - 1];
        assert!(mid[1] > 1.1, "migration cell at rail after AAP1");
        let end = wf.last().unwrap();
        assert!(end[2] > 1.1, "dst at rail after AAP2");
    }
}
