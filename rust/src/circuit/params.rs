//! DRAM cell & circuit parameters across technology nodes — the paper's
//! Table 1, plus derived quantities the transient model consumes.
//!
//! The 45/22 nm rows follow PTM transistor parameters; 20 nm and 10 nm are
//! scaled estimates (as in the paper, §4.2). Access-transistor
//! on-resistance is derived from a long-channel estimate
//! R_on ≈ L / (W · k′ · (V_boost − V_th)) normalized to ~15 kΩ at 22 nm —
//! DRAM access devices are deliberately weak; the exact value only moves
//! the settling time, which the sense window comfortably covers.

/// One technology node's cell/circuit parameters (one column of Table 1).
#[derive(Clone, Debug, PartialEq)]
pub struct TechNode {
    pub name: &'static str,
    pub vdd: f64,
    pub wl_boost: f64,
    /// storage cell capacitance, F
    pub c_cell: f64,
    /// access transistor length / width, m
    pub access_l: f64,
    pub access_w: f64,
    /// sense-amp NMOS width, m
    pub sa_nmos_w: f64,
    /// bitline resistance per cell, Ω
    pub bl_r_per_cell: f64,
    /// bitline capacitance per cell, F
    pub bl_c_per_cell: f64,
    /// wordline rise time, s
    pub t_rise: f64,
    /// derived access on-resistance, Ω
    pub r_on: f64,
    /// sense-amp regeneration rate, 1/s
    pub sa_gain: f64,
}

/// Rows of Table 1. The paper validates the shift at 45/22/20/10 nm;
/// 600/180 nm are included for the historical scaling context.
impl TechNode {
    pub fn n600() -> Self {
        TechNode {
            name: "600nm", vdd: 3.3, wl_boost: 5.0, c_cell: 120e-15,
            access_l: 0.6e-6, access_w: 1.2e-6, sa_nmos_w: 140e-6,
            bl_r_per_cell: 1.0, bl_c_per_cell: 2.0e-15, t_rise: 5e-9,
            r_on: 6e3, sa_gain: 1.0e9,
        }
    }

    pub fn n180() -> Self {
        TechNode {
            name: "180nm", vdd: 1.8, wl_boost: 3.3, c_cell: 50e-15,
            access_l: 0.18e-6, access_w: 0.36e-6, sa_nmos_w: 42e-6,
            bl_r_per_cell: 0.4, bl_c_per_cell: 0.8e-15, t_rise: 2e-9,
            r_on: 9e3, sa_gain: 1.3e9,
        }
    }

    pub fn n45() -> Self {
        TechNode {
            name: "45nm", vdd: 1.5, wl_boost: 3.0, c_cell: 30e-15,
            access_l: 45e-9, access_w: 180e-9, sa_nmos_w: 10.5e-6,
            bl_r_per_cell: 0.2, bl_c_per_cell: 0.40e-15, t_rise: 0.7e-9,
            r_on: 12e3, sa_gain: 1.6e9,
        }
    }

    pub fn n22() -> Self {
        TechNode {
            name: "22nm", vdd: 1.2, wl_boost: 2.5, c_cell: 25e-15,
            access_l: 22e-9, access_w: 44e-9, sa_nmos_w: 7e-6,
            bl_r_per_cell: 0.12, bl_c_per_cell: 0.24e-15, t_rise: 0.5e-9,
            r_on: 15e3, sa_gain: 2.0e9,
        }
    }

    pub fn n20() -> Self {
        TechNode {
            name: "20nm", vdd: 1.1, wl_boost: 2.4, c_cell: 25e-15,
            access_l: 20e-9, access_w: 40e-9, sa_nmos_w: 6e-6,
            bl_r_per_cell: 0.11, bl_c_per_cell: 0.22e-15, t_rise: 0.4e-9,
            r_on: 16e3, sa_gain: 2.1e9,
        }
    }

    pub fn n10() -> Self {
        TechNode {
            name: "10nm", vdd: 1.1, wl_boost: 2.2, c_cell: 18e-15,
            access_l: 12e-9, access_w: 25e-9, sa_nmos_w: 4.5e-6,
            bl_r_per_cell: 0.10, bl_c_per_cell: 0.18e-15, t_rise: 0.3e-9,
            r_on: 20e3, sa_gain: 2.2e9,
        }
    }

    /// All Table-1 nodes in paper order.
    pub fn all() -> Vec<TechNode> {
        vec![Self::n600(), Self::n180(), Self::n45(), Self::n22(), Self::n20(), Self::n10()]
    }

    /// The nodes whose shift operation the paper validates in LTSPICE.
    pub fn validated() -> Vec<TechNode> {
        vec![Self::n45(), Self::n22(), Self::n20(), Self::n10()]
    }

    pub fn by_name(name: &str) -> Option<TechNode> {
        Self::all().into_iter().find(|n| n.name == name)
    }

    /// Total bitline capacitance for a 512-row open-bitline segment plus
    /// sense-amp parasitics.
    pub fn c_bitline(&self, rows: usize) -> f64 {
        self.bl_c_per_cell * rows as f64 + 15e-15
    }

    /// Nominal Monte-Carlo parameter vector (the L1 kernel's 16-float
    /// layout; see python/compile/kernels/common.py) for a cell storing
    /// `bit`, at full retention.
    pub fn mc_nominal(&self, bit: bool) -> [f32; 16] {
        let c_bl = self.c_bitline(512) as f32;
        [
            self.c_cell as f32,       // C_SRC
            self.c_cell as f32,       // C_MIG
            self.c_cell as f32,       // C_DST
            c_bl,                     // C_BLA
            c_bl,                     // C_BLB
            self.r_on as f32,         // R_SRC
            self.r_on as f32,         // R_MIG_A
            self.r_on as f32,         // R_MIG_B
            self.r_on as f32,         // R_DST
            self.vdd as f32,          // VDD
            self.t_rise as f32,       // T_RISE
            self.sa_gain as f32,      // SA_GAIN
            0.0,                      // OFF_A
            0.0,                      // OFF_B
            if bit { self.vdd as f32 } else { 0.0 }, // V_SRC0
            0.0,                      // V_DST0
        ]
    }

    /// Charge-sharing read margin estimate ΔV = (V_cell − V_DD/2) ·
    /// C_cell / (C_cell + C_BL) — the first-order signal the sense amp
    /// must resolve.
    pub fn charge_share_margin(&self, rows: usize) -> f64 {
        let c_bl = self.c_bitline(rows);
        (self.vdd / 2.0) * self.c_cell / (self.c_cell + c_bl)
    }
}

/// Kernel parameter-vector indices (mirror of kernels/common.py).
pub mod pidx {
    pub const C_SRC: usize = 0;
    pub const C_MIG: usize = 1;
    pub const C_DST: usize = 2;
    pub const C_BLA: usize = 3;
    pub const C_BLB: usize = 4;
    pub const R_SRC: usize = 5;
    pub const R_MIG_A: usize = 6;
    pub const R_MIG_B: usize = 7;
    pub const R_DST: usize = 8;
    pub const VDD: usize = 9;
    pub const T_RISE: usize = 10;
    pub const SA_GAIN: usize = 11;
    pub const OFF_A: usize = 12;
    pub const OFF_B: usize = 13;
    pub const V_SRC0: usize = 14;
    pub const V_DST0: usize = 15;
    pub const N_PARAMS: usize = 16;

    pub const SENSE_A: usize = 0;
    pub const SENSE_B: usize = 1;
    pub const V_DST_F: usize = 2;
    pub const V_MIG_F: usize = 3;
    pub const V_SRC_F: usize = 4;
    pub const V_BLB_F: usize = 5;
    pub const N_OUT: usize = 6;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        // spot-check the published Table 1 cells
        let n22 = TechNode::n22();
        assert_eq!(n22.vdd, 1.2);
        assert_eq!(n22.c_cell, 25e-15);
        assert_eq!(n22.access_l, 22e-9);
        assert_eq!(n22.access_w, 44e-9);
        let n600 = TechNode::n600();
        assert_eq!(n600.vdd, 3.3);
        assert_eq!(n600.c_cell, 120e-15);
        let n10 = TechNode::n10();
        assert_eq!(n10.c_cell, 18e-15);
        assert_eq!(n10.t_rise, 0.3e-9);
    }

    #[test]
    fn monotone_scaling() {
        // Table 1's trends: vdd, cell cap, trise all shrink with the node
        let all = TechNode::all();
        for w in all.windows(2) {
            assert!(w[0].vdd >= w[1].vdd, "{} vs {}", w[0].name, w[1].name);
            assert!(w[0].c_cell >= w[1].c_cell);
            assert!(w[0].t_rise >= w[1].t_rise);
            assert!(w[0].bl_c_per_cell >= w[1].bl_c_per_cell);
        }
    }

    #[test]
    fn margins_shrink_toward_10nm() {
        // cell cap and VDD shrink faster than the bitline load at the end
        // of the roadmap: 10 nm has the smallest absolute margin (45 vs 22
        // are within a few mV of each other because BL C/cell halves too)
        let m45 = TechNode::n45().charge_share_margin(512);
        let m22 = TechNode::n22().charge_share_margin(512);
        let m10 = TechNode::n10().charge_share_margin(512);
        assert!(m45 > m10 && m22 > m10, "{m45} {m22} {m10}");
        // 22 nm margin ~ tens of millivolts (sanity for the SA to resolve)
        assert!((0.03..0.15).contains(&m22), "margin {m22}");
    }

    #[test]
    fn nominal_vector_layout() {
        let p = TechNode::n22().mc_nominal(true);
        assert_eq!(p[pidx::VDD], 1.2);
        assert_eq!(p[pidx::V_SRC0], 1.2);
        let p0 = TechNode::n22().mc_nominal(false);
        assert_eq!(p0[pidx::V_SRC0], 0.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(TechNode::by_name("22nm").unwrap().name, "22nm");
        assert!(TechNode::by_name("7nm").is_none());
        assert_eq!(TechNode::validated().len(), 4);
    }
}
