//! §4.2 circuit-level validation checks, run against a waveform trace
//! (native or the `shift_waveform` PJRT artifact).
//!
//! The paper validates six properties; each gets an explicit check here:
//! 1. successful data transfer,
//! 2. correct shift (bit appears at the destination),
//! 3. data preservation in surrounding cells,
//! 4. signal integrity (voltages within rails, SA resolves correctly),
//! 5. proper charge transfer through the migration cell,
//! 6. complete write-back (retention-worthy final level).

use crate::circuit::native::{shift_waveform, TransientCfg};
use crate::circuit::params::TechNode;

/// Outcome of the six §4.2 checks for one (node, bit) case.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub node: &'static str,
    pub bit: bool,
    pub data_transfer: bool,
    pub correct_shift: bool,
    pub preservation: bool,
    pub signal_integrity: bool,
    pub charge_transfer: bool,
    pub writeback: bool,
}

impl ValidationReport {
    pub fn all_pass(&self) -> bool {
        self.data_transfer
            && self.correct_shift
            && self.preservation
            && self.signal_integrity
            && self.charge_transfer
            && self.writeback
    }
}

/// Run the checks on a waveform trace (rows of [v_src, v_mig, v_dst,
/// v_bl_a, v_bl_b]).
pub fn validate_trace(
    node: &TechNode,
    bit: bool,
    trace: &[[f32; 5]],
    steps_per_aap: usize,
) -> ValidationReport {
    let vdd = node.vdd as f32;
    let rail_hi = 0.9 * vdd;
    let rail_lo = 0.1 * vdd;
    let end1 = steps_per_aap.min(trace.len()) - 1;
    let at_rail = |v: f32| if bit { v > rail_hi } else { v < rail_lo };

    let mid = trace[end1];
    let end = *trace.last().unwrap();

    // 1. data transfer: migration cell captured the bit in AAP 1
    let data_transfer = at_rail(mid[1]);
    // 2. correct shift: destination carries the bit after AAP 2
    let correct_shift = at_rail(end[2]);
    // 3. preservation: destination is untouched during AAP 1 and the source
    //    is restored to full level by the end (non-destructive copy)
    let preservation = (mid[2] - trace[0][2]).abs() < 0.05 * vdd && at_rail(end[0]);
    // 4. signal integrity: every node stays within the rails (+5 % guard)
    let signal_integrity = trace.iter().all(|s| {
        s.iter().all(|&v| (-0.05 * vdd..=1.05 * vdd).contains(&v))
    });
    // 5. charge transfer: bitline B regenerated to the bit's rail in AAP 2
    let charge_transfer = at_rail(end[4]);
    // 6. complete write-back: final dst level within 10 % of rail
    let writeback = if bit { end[2] > rail_hi } else { end[2] < rail_lo };

    ValidationReport {
        node: node.name,
        bit,
        data_transfer,
        correct_shift,
        preservation,
        signal_integrity,
        charge_transfer,
        writeback,
    }
}

/// Validate one (node, bit) case with the native transient engine.
pub fn validate_native(node: &TechNode, bit: bool) -> ValidationReport {
    let cfg = TransientCfg::default();
    let p = node.mc_nominal(bit);
    let trace = shift_waveform(&p, &cfg);
    validate_trace(node, bit, &trace, cfg.steps_per_aap())
}

/// Validate the paper's full §4.2 matrix: 4 nodes × both bit values.
pub fn validate_all_nodes() -> Vec<ValidationReport> {
    let mut out = Vec::new();
    for node in TechNode::validated() {
        for bit in [false, true] {
            out.push(validate_native(&node, bit));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::params::pidx::*;

    #[test]
    fn full_matrix_passes() {
        for r in validate_all_nodes() {
            assert!(r.all_pass(), "{:?}", r);
        }
    }

    #[test]
    fn corrupted_trace_fails_integrity() {
        let node = TechNode::n22();
        let cfg = TransientCfg::default();
        let p = node.mc_nominal(true);
        let mut trace = shift_waveform(&p, &cfg);
        let n = trace.len();
        trace[n / 2][3] = 2.0 * node.vdd as f32; // bitline overshoot
        let r = validate_trace(&node, true, &trace, cfg.steps_per_aap());
        assert!(!r.signal_integrity);
        assert!(!r.all_pass());
    }

    #[test]
    fn broken_cell_fails_transfer() {
        let node = TechNode::n22();
        let cfg = TransientCfg::default();
        let mut p = node.mc_nominal(true);
        p[R_MIG_A] = 1e9; // open access transistor: no charge transfer
        let trace = crate::circuit::native::shift_waveform(&p, &cfg);
        let r = validate_trace(&node, true, &trace, cfg.steps_per_aap());
        assert!(!r.data_transfer);
    }

    #[test]
    fn report_uses_pidx_consistently() {
        // guard: the trace layout matches the artifact node order
        assert_eq!(crate::circuit::params::pidx::V_DST_F, 2);
    }
}
