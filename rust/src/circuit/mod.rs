//! Circuit-level layer (the LTSPICE substitute): technology-node
//! parameters (Table 1), the lumped-RC transient engine (native oracle +
//! PJRT-executed JAX/Pallas artifact), the §4.2 validation checks, and the
//! Monte-Carlo process-variation study (Table 4).

pub mod montecarlo;
pub mod native;
pub mod params;
pub mod validation;

pub use montecarlo::{Backend, McLevelResult, MonteCarlo};
pub use native::{shift_transient, shift_waveform, TransientCfg};
pub use params::TechNode;
pub use validation::{validate_all_nodes, validate_native, ValidationReport};
