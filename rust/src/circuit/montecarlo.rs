//! Monte-Carlo process-variation study (paper §5.2, Table 4).
//!
//! Protocol (mirrors the paper's LTSPICE methodology): for each variation
//! level ±X %, draw `trials` independent parameter vectors — every physical
//! parameter (cell caps, bitline caps, on-resistances, wordline rise time)
//! perturbed multiplicatively by `N(0, X)`, the sense-amp input offset by
//! `N(0, sa_offset_frac·X·VDD)`, and the stored level subject to retention
//! droop — run the two-AAP shift transient for each, and classify against
//! the §4.2 criteria:
//!
//! * correct sense direction at both AAPs (margin above threshold),
//! * complete write-back (final dst level within `writeback_frac` of rail).
//!
//! The transient physics runs either through the AOT-compiled JAX/Pallas
//! artifact on PJRT ([`Backend::Pjrt`], the production path) or the native
//! oracle ([`Backend::Native`], bit-compatible fallback); both are checked
//! against each other in `rust/tests/runtime_roundtrip.rs`.

use crate::circuit::native::{shift_transient, TransientCfg};
use crate::circuit::params::{pidx::*, TechNode};
use crate::config::McConfig;
use crate::runtime::{Manifest, Runtime};
use crate::util::stats::wilson_interval;
use crate::util::Rng;

/// Which engine integrates the transient.
pub enum Backend<'a> {
    /// AOT JAX/Pallas artifact on the PJRT CPU client
    Pjrt(&'a Runtime, &'a Manifest),
    /// in-crate f32 oracle
    Native,
}

/// Failure statistics for one variation level (one cell of Table 4).
#[derive(Clone, Debug)]
pub struct McLevelResult {
    pub level: f64,
    pub trials: usize,
    pub failures: usize,
}

impl McLevelResult {
    pub fn failure_rate(&self) -> f64 {
        self.failures as f64 / self.trials as f64
    }

    /// 95 % Wilson interval on the failure rate.
    pub fn ci95(&self) -> (f64, f64) {
        wilson_interval(self.failures as u64, self.trials as u64, 1.96)
    }
}

/// The Monte-Carlo harness.
pub struct MonteCarlo {
    pub mc: McConfig,
    pub node: TechNode,
    pub tcfg: TransientCfg,
}

impl MonteCarlo {
    pub fn new(mc: McConfig, node: TechNode) -> Self {
        mc.validate().expect("invalid MC config");
        MonteCarlo { mc, node, tcfg: TransientCfg::default() }
    }

    /// Draw one trial's parameter vector. Returns (params, stored bit).
    pub fn draw(&self, rng: &mut Rng, level: f64) -> ([f32; N_PARAMS], bool) {
        let mut p = self.node.mc_nominal(true);
        let bit = rng.bool();
        let vdd = self.node.vdd;
        let perturb = |rng: &mut Rng, nominal: f32| -> f32 {
            (nominal as f64 * (1.0 + rng.normal(0.0, level))).max(nominal as f64 * 0.05)
                as f32
        };
        for idx in [C_SRC, C_MIG, C_DST, C_BLA, C_BLB, R_SRC, R_MIG_A, R_MIG_B, R_DST, T_RISE]
        {
            p[idx] = perturb(rng, p[idx]);
        }
        let off_sigma = (self.mc.sa_offset_frac * level).min(self.mc.sa_offset_cap) * vdd;
        p[OFF_A] = rng.normal(0.0, off_sigma) as f32;
        p[OFF_B] = rng.normal(0.0, off_sigma) as f32;
        // retention droop: a '1' decays toward GND, a '0' leaks up slightly
        let droop = self.mc.retention_droop * (1.0 + rng.normal(0.0, level)).max(0.0);
        p[V_SRC0] = if bit {
            (vdd * (1.0 - droop)).clamp(0.0, vdd) as f32
        } else {
            (vdd * droop * 0.5).clamp(0.0, vdd) as f32
        };
        p[V_DST0] = if rng.bool() { vdd as f32 } else { 0.0 };
        (p, bit)
    }

    /// §4.2 pass/fail classification of one trial's physical outputs.
    pub fn classify(&self, out: &[f32], bit: bool) -> bool {
        let vdd = self.node.vdd as f32;
        let sign = if bit { 1.0f32 } else { -1.0 };
        let margin_ok = sign * out[SENSE_A] > self.mc.margin_threshold_v as f32
            && sign * out[SENSE_B] > self.mc.margin_threshold_v as f32;
        let target = if bit { vdd } else { 0.0 };
        let writeback_ok =
            (out[V_DST_F] - target).abs() < self.mc.writeback_frac as f32 * vdd;
        margin_ok && writeback_ok
    }

    /// Run one variation level through the chosen backend.
    pub fn run_level(&self, backend: &Backend, level: f64, seed: u64) -> McLevelResult {
        let mut rng = Rng::new(seed ^ (level * 1e6) as u64);
        let trials = self.mc.trials;
        let mut failures = 0usize;
        match backend {
            Backend::Native => {
                for _ in 0..trials {
                    let (p, bit) = self.draw(&mut rng, level);
                    let out = shift_transient(&p, &self.tcfg);
                    if !self.classify(&out, bit) {
                        failures += 1;
                    }
                }
            }
            Backend::Pjrt(rt, m) => {
                assert_eq!(m.n_params, N_PARAMS);
                let batch = m.mc_batch;
                let mut done = 0usize;
                while done < trials {
                    let take = (trials - done).min(batch);
                    let mut input = Vec::with_capacity(batch * N_PARAMS);
                    let mut bits = Vec::with_capacity(batch);
                    for _ in 0..take {
                        let (p, bit) = self.draw(&mut rng, level);
                        input.extend_from_slice(&p);
                        bits.push(bit);
                    }
                    // pad the ragged tail with nominal vectors (ignored)
                    for _ in take..batch {
                        input.extend_from_slice(&self.node.mc_nominal(true));
                    }
                    let out = rt
                        .exec_f32("shift_mc", &input, &[batch as i64, N_PARAMS as i64])
                        .expect("MC artifact execution");
                    for (t, &bit) in bits.iter().enumerate() {
                        let o = &out[t * m.n_out..(t + 1) * m.n_out];
                        if !self.classify(o, bit) {
                            failures += 1;
                        }
                    }
                    done += take;
                }
            }
        }
        McLevelResult { level, trials, failures }
    }

    /// Run the full Table-4 sweep.
    pub fn run(&self, backend: &Backend) -> Vec<McLevelResult> {
        self.mc
            .levels
            .iter()
            .map(|&lvl| self.run_level(backend, lvl, self.mc.seed))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn harness(trials: usize) -> MonteCarlo {
        let mut mc = McConfig::paper();
        mc.trials = trials;
        MonteCarlo::new(mc, TechNode::n22())
    }

    #[test]
    fn zero_variation_never_fails() {
        // Table 4: ±0 % → 0.00 %
        let h = harness(2_000);
        let r = h.run_level(&Backend::Native, 0.0, 1);
        assert_eq!(r.failures, 0, "nominal circuit must be perfect");
    }

    #[test]
    fn failure_rate_grows_superlinearly() {
        // Table 4 shape: 0 % → 0.5 % → 14 % → 30 %
        let h = harness(4_000);
        let r5 = h.run_level(&Backend::Native, 0.05, 2).failure_rate();
        let r10 = h.run_level(&Backend::Native, 0.10, 2).failure_rate();
        let r20 = h.run_level(&Backend::Native, 0.20, 2).failure_rate();
        assert!(r5 < r10 && r10 < r20, "monotone: {r5} {r10} {r20}");
        assert!(r10 / r5.max(1e-4) > 4.0, "superlinear onset: {r5} -> {r10}");
        assert!((0.001..0.02).contains(&r5), "±5% rate {r5}");
        assert!((0.06..0.22).contains(&r10), "±10% rate {r10}");
        assert!((0.20..0.48).contains(&r20), "±20% rate {r20}");
    }

    #[test]
    fn draw_respects_level_zero() {
        let h = harness(10);
        let mut rng = Rng::new(3);
        let (p, _) = h.draw(&mut rng, 0.0);
        let nominal = TechNode::n22().mc_nominal(true);
        for idx in [C_SRC, C_BLA, R_SRC, T_RISE] {
            assert_eq!(p[idx], nominal[idx], "param {idx} unperturbed at ±0%");
        }
        assert_eq!(p[OFF_A], 0.0);
    }

    #[test]
    fn classify_criteria() {
        let h = harness(10);
        // good '1' trial
        assert!(h.classify(&[0.08, 0.08, 1.19, 1.2, 1.2, 1.2], true));
        // flipped sense
        assert!(!h.classify(&[-0.02, 0.08, 1.19, 1.2, 1.2, 1.2], true));
        // incomplete write-back
        assert!(!h.classify(&[0.08, 0.08, 0.7, 1.2, 1.2, 1.2], true));
        // good '0' trial
        assert!(h.classify(&[-0.08, -0.08, 0.01, 0.0, 0.0, 0.0], false));
    }

    #[test]
    fn deterministic_given_seed() {
        let h = harness(500);
        let a = h.run_level(&Backend::Native, 0.1, 7);
        let b = h.run_level(&Backend::Native, 0.1, 7);
        assert_eq!(a.failures, b.failures);
    }
}
