//! Baseline cost models for the paper's comparisons: conventional CPU data
//! movement (§5.1.5), SIMDRAM's vertical layout + transposition, DRISA's
//! in-situ shifters, and Ambit (§5.1.6, Table 5).
//!
//! Each baseline implements [`ShiftApproach`]: the per-full-row-shift
//! energy/latency cost and the architectural overheads, so the comparison
//! bench regenerates the paper's who-wins-by-what-factor narrative.

pub mod cpu_movement;
pub mod drisa;
pub mod simdram;

pub use cpu_movement::CpuMovement;
pub use drisa::Drisa;
pub use simdram::Simdram;

/// A design point that can shift one full DRAM row by one bit position.
#[derive(Clone, Debug)]
pub struct ShiftCost {
    /// energy for one full-row 1-bit shift, nJ
    pub energy_nj: f64,
    /// latency for one full-row 1-bit shift, ns
    pub latency_ns: f64,
    /// one-time per-operand overhead (SIMDRAM transposition), nJ/ns
    pub setup_energy_nj: f64,
    pub setup_latency_ns: f64,
}

impl ShiftCost {
    /// Amortized cost of `n` successive shifts of the same operand.
    pub fn total_energy_nj(&self, n: usize) -> f64 {
        self.setup_energy_nj + n as f64 * self.energy_nj
    }

    pub fn total_latency_ns(&self, n: usize) -> f64 {
        self.setup_latency_ns + n as f64 * self.latency_ns
    }
}

/// Interface all baselines (and our design) expose to the comparison bench.
pub trait ShiftApproach {
    fn name(&self) -> &'static str;
    /// cost to shift a `row_bytes` row by one position
    fn shift_cost(&self, row_bytes: usize) -> ShiftCost;
    /// DRAM-die area overhead (fraction)
    fn area_overhead(&self) -> f64;
    /// whether data must leave its conventional horizontal layout
    fn needs_transposition(&self) -> bool;
}

/// Our migration-cell design as a [`ShiftApproach`] (values from the
/// calibrated simulator, see `sim::workload`).
pub struct MigrationShift {
    pub energy_nj: f64,
    pub latency_ns: f64,
    pub area: f64,
}

impl MigrationShift {
    pub fn from_config(cfg: &crate::config::DramConfig) -> Self {
        let aap = Command4aap::cost(cfg);
        MigrationShift {
            energy_nj: aap.0,
            latency_ns: aap.1,
            area: crate::layout::migration_overhead(&cfg.geometry),
        }
    }
}

struct Command4aap;

impl Command4aap {
    /// (energy nJ, latency ns) of the 4-AAP shift under `cfg`.
    fn cost(cfg: &crate::config::DramConfig) -> (f64, f64) {
        let e_act = cfg.energy.e_act_pj(&cfg.timing);
        let e = 4.0 * (2.0 * e_act + cfg.energy.e_pre_pj) / 1e3;
        let t = 4.0 * cfg.timing.t_aap() as f64 / 1e3;
        (e, t)
    }
}

impl ShiftApproach for MigrationShift {
    fn name(&self) -> &'static str {
        "Migration cells (ours)"
    }

    fn shift_cost(&self, row_bytes: usize) -> ShiftCost {
        // the 4-AAP procedure always moves a full row; cost is independent
        // of how much of the row the caller cares about
        let _ = row_bytes;
        ShiftCost {
            energy_nj: self.energy_nj,
            latency_ns: self.latency_ns,
            setup_energy_nj: 0.0,
            setup_latency_ns: 0.0,
        }
    }

    fn area_overhead(&self) -> f64 {
        self.area
    }

    fn needs_transposition(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DramConfig;

    #[test]
    fn ours_matches_simulator_headline() {
        let m = MigrationShift::from_config(&DramConfig::ddr3_1333_4gb());
        let c = m.shift_cost(8192);
        assert!((c.energy_nj - 31.32).abs() < 0.1, "{}", c.energy_nj);
        assert!((c.latency_ns - 210.0).abs() < 0.1);
        assert!(m.area_overhead() < 0.01);
        assert!(!m.needs_transposition());
    }

    #[test]
    fn amortization_identity() {
        let c = ShiftCost {
            energy_nj: 10.0,
            latency_ns: 100.0,
            setup_energy_nj: 1000.0,
            setup_latency_ns: 5000.0,
        };
        assert_eq!(c.total_energy_nj(10), 1100.0);
        assert_eq!(c.total_latency_ns(10), 6000.0);
    }
}
