//! DRISA baseline (§5.1.6): dedicated shifter circuits beneath the sense
//! amplifiers move data between adjacent bitlines directly.
//!
//! Paper-reported characteristics: ~5–20 nJ per shift, ~20–40 ns per bit
//! position, area overhead 6.8 % (3T1C) up to 34–60 % (1T1C logic
//! variants). Fast and transposition-free, but the shifters replicate per
//! bitline and the logic variants pay heavily in die area.

use crate::baselines::{ShiftApproach, ShiftCost};

/// DRISA design variants (Table 5 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrisaVariant {
    T3C1,
    Nor1T1C,
    Mixed1T1C,
    Adder1T1C,
}

#[derive(Clone, Debug)]
pub struct Drisa {
    pub variant: DrisaVariant,
    /// energy per full-row 1-bit shift, nJ (paper range 5–20)
    pub shift_nj: f64,
    /// latency per bit position, ns (paper range 20–40)
    pub shift_ns: f64,
}

impl Drisa {
    pub fn new(variant: DrisaVariant) -> Self {
        // the 3T1C design computes in-cell and shifts slower; the 1T1C
        // variants add faster dedicated logic at higher area cost
        let (shift_nj, shift_ns) = match variant {
            DrisaVariant::T3C1 => (12.5, 40.0),
            DrisaVariant::Nor1T1C => (10.0, 30.0),
            DrisaVariant::Mixed1T1C => (12.0, 25.0),
            DrisaVariant::Adder1T1C => (20.0, 20.0),
        };
        Drisa { variant, shift_nj, shift_ns }
    }

    pub fn all_variants() -> Vec<Drisa> {
        [
            DrisaVariant::T3C1,
            DrisaVariant::Nor1T1C,
            DrisaVariant::Mixed1T1C,
            DrisaVariant::Adder1T1C,
        ]
        .into_iter()
        .map(Drisa::new)
        .collect()
    }
}

impl ShiftApproach for Drisa {
    fn name(&self) -> &'static str {
        match self.variant {
            DrisaVariant::T3C1 => "DRISA 3T1C",
            DrisaVariant::Nor1T1C => "DRISA 1T1C-nor",
            DrisaVariant::Mixed1T1C => "DRISA 1T1C-mixed",
            DrisaVariant::Adder1T1C => "DRISA 1T1C-adder",
        }
    }

    fn shift_cost(&self, _row_bytes: usize) -> ShiftCost {
        ShiftCost {
            energy_nj: self.shift_nj,
            latency_ns: self.shift_ns,
            setup_energy_nj: 0.0,
            setup_latency_ns: 0.0,
        }
    }

    fn area_overhead(&self) -> f64 {
        match self.variant {
            DrisaVariant::T3C1 => 0.068,
            DrisaVariant::Nor1T1C => 0.34,
            DrisaVariant::Mixed1T1C => 0.40,
            DrisaVariant::Adder1T1C => 0.60,
        }
    }

    fn needs_transposition(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ranges() {
        for d in Drisa::all_variants() {
            let c = d.shift_cost(8192);
            assert!((5.0..=20.0).contains(&c.energy_nj), "{}", d.name());
            assert!((20.0..=40.0).contains(&c.latency_ns), "{}", d.name());
        }
    }

    #[test]
    fn faster_but_larger_than_ours() {
        // the paper's §5.1.6 narrative: DRISA wins latency, loses area
        let ours_ns = 210.0;
        let ours_area = 0.0078;
        for d in Drisa::all_variants() {
            assert!(d.shift_cost(8192).latency_ns < ours_ns);
            assert!(d.area_overhead() > ours_area);
        }
    }

    #[test]
    fn comparable_energy_per_kb() {
        // §5.1.6: 4 nJ/KB (ours) vs 5–20 nJ per 8 KB shift → 0.6–2.5 nJ/KB
        // ... DRISA's absolute shift energy overlaps ours
        let d = Drisa::new(DrisaVariant::T3C1);
        let per_kb = d.shift_cost(8192).energy_nj / 8.0;
        assert!((0.5..3.0).contains(&per_kb));
    }

    #[test]
    fn area_ladder() {
        let v = Drisa::all_variants();
        assert!(v[0].area_overhead() < v[1].area_overhead());
        assert!(v[1].area_overhead() < v[2].area_overhead());
        assert!(v[2].area_overhead() < v[3].area_overhead());
    }
}
