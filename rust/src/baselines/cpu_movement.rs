//! Conventional data movement baseline (§5.1.5): read the row to the CPU,
//! shift there, write it back.
//!
//! The paper assumes ~10–15 nJ per 64 B DDR3 transfer; an 8 KB row is 128
//! transfers each way. The paper's "40–60×" headline compares against the
//! **read** leg alone (1,280–1,920 nJ vs 31–32 nJ); the full round trip is
//! ~80–120×. We model both (see `EXPERIMENTS.md`).

use crate::baselines::{ShiftApproach, ShiftCost};

/// CPU round-trip cost model.
#[derive(Clone, Debug)]
pub struct CpuMovement {
    /// energy per 64 B off-chip transfer, nJ (paper range 10–15)
    pub nj_per_64b: f64,
    /// sustained channel bandwidth, GB/s (DDR3-1333 ≈ 10.7)
    pub bandwidth_gbs: f64,
    /// CPU-side shift throughput, GB/s (memcpy-class word shifting)
    pub cpu_shift_gbs: f64,
}

impl Default for CpuMovement {
    fn default() -> Self {
        CpuMovement { nj_per_64b: 12.5, bandwidth_gbs: 10.7, cpu_shift_gbs: 16.0 }
    }
}

impl CpuMovement {
    pub fn paper_low() -> Self {
        CpuMovement { nj_per_64b: 10.0, ..Self::default() }
    }

    pub fn paper_high() -> Self {
        CpuMovement { nj_per_64b: 15.0, ..Self::default() }
    }

    fn transfers(row_bytes: usize) -> f64 {
        (row_bytes as f64 / 64.0).ceil()
    }

    /// Energy of the read leg only (the paper's §5.1.5 comparison basis).
    pub fn read_energy_nj(&self, row_bytes: usize) -> f64 {
        Self::transfers(row_bytes) * self.nj_per_64b
    }

    /// Energy of the full read + writeback round trip.
    pub fn roundtrip_energy_nj(&self, row_bytes: usize) -> f64 {
        2.0 * self.read_energy_nj(row_bytes)
    }

    /// Latency of moving the row both ways plus the CPU shift.
    pub fn roundtrip_latency_ns(&self, row_bytes: usize) -> f64 {
        let b = row_bytes as f64;
        let move_ns = 2.0 * b / self.bandwidth_gbs; // GB/s == B/ns
        let shift_ns = b / self.cpu_shift_gbs;
        move_ns + shift_ns
    }
}

impl ShiftApproach for CpuMovement {
    fn name(&self) -> &'static str {
        "CPU read-shift-write"
    }

    fn shift_cost(&self, row_bytes: usize) -> ShiftCost {
        ShiftCost {
            energy_nj: self.roundtrip_energy_nj(row_bytes),
            latency_ns: self.roundtrip_latency_ns(row_bytes),
            setup_energy_nj: 0.0,
            setup_latency_ns: 0.0,
        }
    }

    fn area_overhead(&self) -> f64 {
        0.0
    }

    fn needs_transposition(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_read_leg_range() {
        // §5.1.5: 128 transfers, 1,280–1,920 nJ for the read alone
        assert!((CpuMovement::paper_low().read_energy_nj(8192) - 1280.0).abs() < 1.0);
        assert!((CpuMovement::paper_high().read_energy_nj(8192) - 1920.0).abs() < 1.0);
    }

    #[test]
    fn headline_energy_ratio_40_to_60x() {
        // ours ≈ 31.3 nJ; read-leg ratio must land in the paper's 40–60×
        let ours = 31.32;
        let lo = CpuMovement::paper_low().read_energy_nj(8192) / ours;
        let hi = CpuMovement::paper_high().read_energy_nj(8192) / ours;
        assert!((39.0..45.0).contains(&lo), "low ratio {lo}");
        assert!((58.0..65.0).contains(&hi), "high ratio {hi}");
    }

    #[test]
    fn roundtrip_doubles_read() {
        let c = CpuMovement::default();
        assert_eq!(c.roundtrip_energy_nj(8192), 2.0 * c.read_energy_nj(8192));
    }

    #[test]
    fn latency_dominated_by_movement() {
        let c = CpuMovement::default();
        let t = c.roundtrip_latency_ns(8192);
        // two 8 KB moves at ~10.7 GB/s ≈ 1.5 µs ⊕ CPU shift 0.5 µs
        assert!((1_500.0..2_500.0).contains(&t), "latency {t} ns");
    }
}
