//! SIMDRAM baseline (§5.1.6): vertical (bit-serial) layout turns a shift
//! into a single RowClone, but every operand must first be transposed from
//! the conventional horizontal layout and transposed back afterwards.
//!
//! Cost model: the shift itself is one AAP (~50–100 ns, one RowClone); the
//! transposition of an 8 KB row costs thousands of column accesses —
//! the paper cites several µs to tens of µs and 1,000–10,000 nJ for large
//! operands. We charge transposition once per operand (setup), then each
//! shift is a row copy; the back-transposition is folded into the setup
//! figure (both directions happen once per operand).

use crate::baselines::{ShiftApproach, ShiftCost};

#[derive(Clone, Debug)]
pub struct Simdram {
    /// one in-DRAM row copy (RowClone AAP), nJ / ns
    pub rowclone_nj: f64,
    pub rowclone_ns: f64,
    /// transposition cost per KB of operand (both directions), nJ / ns
    pub transpose_nj_per_kb: f64,
    pub transpose_ns_per_kb: f64,
}

impl Default for Simdram {
    fn default() -> Self {
        Simdram {
            rowclone_nj: 7.83,          // 2 ACT + PRE, same DDR3 energy model
            rowclone_ns: 75.0,          // paper: 50–100 ns
            transpose_nj_per_kb: 687.5, // → 5,500 nJ per 8 KB (1–10 µJ range)
            transpose_ns_per_kb: 1_875.0, // → 15 µs per 8 KB (µs–tens of µs)
        }
    }
}

impl Simdram {
    pub fn transpose_energy_nj(&self, row_bytes: usize) -> f64 {
        self.transpose_nj_per_kb * row_bytes as f64 / 1024.0
    }

    pub fn transpose_latency_ns(&self, row_bytes: usize) -> f64 {
        self.transpose_ns_per_kb * row_bytes as f64 / 1024.0
    }
}

impl ShiftApproach for Simdram {
    fn name(&self) -> &'static str {
        "SIMDRAM (vertical + transposition)"
    }

    fn shift_cost(&self, row_bytes: usize) -> ShiftCost {
        ShiftCost {
            energy_nj: self.rowclone_nj,
            latency_ns: self.rowclone_ns,
            setup_energy_nj: self.transpose_energy_nj(row_bytes),
            setup_latency_ns: self.transpose_latency_ns(row_bytes),
        }
    }

    fn area_overhead(&self) -> f64 {
        0.002 // 0.2 % — in the memory controller, not the DRAM die
    }

    fn needs_transposition(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transposition_dominates_small_shift_counts() {
        // §5.1.6: transposition alone is 100–300× our whole shift (31.3 nJ)
        let s = Simdram::default();
        let ratio = s.transpose_energy_nj(8192) / 31.32;
        assert!((100.0..300.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn per_shift_is_cheap_once_transposed() {
        let s = Simdram::default();
        let c = s.shift_cost(8192);
        assert!(c.energy_nj < 31.32, "a vertical shift is one RowClone");
        assert!((50.0..100.0).contains(&c.latency_ns));
    }

    #[test]
    fn crossover_against_ours() {
        // SIMDRAM amortizes its transposition over many shifts; find the
        // crossover count against our flat 31.3 nJ/shift. With 5.5 µJ setup
        // and ~7.8 nJ/shift it needs ~235 shifts of the same operand.
        let s = Simdram::default();
        let ours_nj = 31.32;
        let mut crossover = None;
        for n in 1..10_000 {
            if s.shift_cost(8192).total_energy_nj(n) < ours_nj * n as f64 {
                crossover = Some(n);
                break;
            }
        }
        let n = crossover.expect("SIMDRAM must eventually win on repeated shifts");
        assert!(
            (100..500).contains(&n),
            "crossover at {n} shifts (paper narrative: transposition only \
             pays off for long chains)"
        );
    }
}
