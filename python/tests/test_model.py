"""L2 model tests: MC entry point, waveform model, tech-node physics."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import common as cm, ref


def test_mc_shapes():
    p = ref.nominal_params_22nm(batch=model.MC_BATCH)
    (out,) = model.shift_mc(p)
    assert out.shape == (model.MC_BATCH, cm.N_OUT)
    assert out.dtype == np.float32


def test_waveform_shapes():
    p = ref.nominal_params_22nm(batch=1)
    (tr,) = model.shift_waveform(p)
    assert tr.shape == (1, model.waveform_len(), 5)


def test_waveform_tells_shift_story():
    """The trace must show: src shared onto blA, SA regenerated, migration
    cell captured; then migration shared onto blB and dst captured."""
    p = ref.nominal_params_22nm(batch=1, bit=1)
    tr = np.asarray(model.shift_waveform(p)[0])[0]  # (T, 5)
    v_src, v_mig, v_dst, v_bla, v_blb = tr.T
    half = len(tr) // 2
    # during AAP1 the migration cell moves from Vdd/2 to rail
    assert v_mig[0] < 0.8
    assert v_mig[half - 1] > 1.1
    # dst untouched during AAP1
    assert abs(v_dst[half - 1] - v_dst[0]) < 0.05
    # during AAP2 dst reaches rail
    assert v_dst[-1] > 1.1
    # bitline A regenerates above precharge during AAP1
    assert v_bla[half - 1] > 1.0


def test_waveform_bit0():
    p = ref.nominal_params_22nm(batch=1, bit=0)
    tr = np.asarray(model.shift_waveform(p)[0])[0]
    assert tr[-1, 2] < 0.05  # dst driven to 0


@settings(max_examples=10, deadline=None)
@given(bit=st.integers(0, 1), droop=st.floats(0.0, 0.15))
def test_mc_consistent_with_waveform_endpoint(bit, droop):
    """The MC output's final dst voltage equals the waveform's last sample
    (same physics, two lowerings)."""
    p = ref.nominal_params_22nm(batch=1, bit=bit)
    if bit:
        p[:, cm.V_SRC0] = 1.2 * (1 - droop)
    out = np.asarray(ref.shift_transient_ref(p))
    tr = np.asarray(model.shift_waveform(p)[0])[0]
    # stride subsampling: last waveform sample is within a few steps of end
    assert abs(out[0, cm.V_DST_F] - tr[-1, 2]) < 0.02


class TestTechNodes:
    """The paper validates 45/22/20/10 nm (Table 1). The shift must work at
    each node's nominal parameters."""

    # vdd, cell cap, bl C/cell, t_rise  (Table 1 columns)
    NODES = {
        "45nm": (1.5, 30e-15, 0.40e-15, 0.7e-9),
        "22nm": (1.2, 25e-15, 0.24e-15, 0.5e-9),
        "20nm": (1.1, 25e-15, 0.22e-15, 0.4e-9),
        "10nm": (1.1, 18e-15, 0.18e-15, 0.3e-9),
    }

    def params_for(self, node, bit):
        vdd, c_cell, c_per_cell, trise = self.NODES[node]
        p = ref.nominal_params_22nm(batch=8, bit=bit, vdd=vdd)
        p[:, [cm.C_SRC, cm.C_MIG, cm.C_DST]] = c_cell
        p[:, [cm.C_BLA, cm.C_BLB]] = c_per_cell * 512 + 15e-15
        p[:, cm.T_RISE] = trise
        p[:, cm.V_SRC0] = vdd if bit else 0.0
        return p

    def test_all_nodes_both_bits(self):
        for node in self.NODES:
            for bit in (0, 1):
                p = self.params_for(node, bit)
                out = np.asarray(ref.shift_transient_ref(p))
                vdd = self.NODES[node][0]
                if bit:
                    assert (out[:, cm.V_DST_F] > 0.9 * vdd).all(), node
                else:
                    assert (out[:, cm.V_DST_F] < 0.1 * vdd).all(), node

    def test_margin_shrinks_with_scaling(self):
        """Smaller nodes have smaller absolute sense margins — the physical
        root of Table 4's variation sensitivity."""
        margins = {}
        for node in self.NODES:
            p = self.params_for(node, 1)
            out = np.asarray(ref.shift_transient_ref(p))
            margins[node] = out[0, cm.SENSE_A]
        assert margins["45nm"] > margins["10nm"]
