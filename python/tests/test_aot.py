"""AOT pipeline tests: the emitted HLO text is well-formed, matches the
manifest, and — executed through XLA from the text — reproduces the jnp
model's numerics (the same round trip the Rust runtime performs)."""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import common as cm, ref


@pytest.fixture(scope="module")
def outdir():
    d = tempfile.mkdtemp(prefix="aot_test_")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", d],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    return d


def test_emits_all_artifacts(outdir):
    for name in ("shift_mc.hlo.txt", "shift_waveform.hlo.txt", "manifest.json"):
        path = os.path.join(outdir, name)
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 0, name


def test_manifest_consistent(outdir):
    with open(os.path.join(outdir, "manifest.json")) as f:
        m = json.load(f)
    assert m["n_params"] == cm.N_PARAMS
    assert m["n_out"] == cm.N_OUT
    assert m["mc_batch"] == model.MC_BATCH
    assert m["mc_batch"] % m["mc_tile"] == 0
    assert m["waveform_len"] == model.waveform_len()
    assert m["format"] == "hlo-text"


def test_hlo_text_mentions_shapes(outdir):
    with open(os.path.join(outdir, "shift_mc.hlo.txt")) as f:
        text = f.read()
    assert f"f32[{model.MC_BATCH},{cm.N_PARAMS}]" in text
    assert f"f32[{model.MC_BATCH},{cm.N_OUT}]" in text
    # the time loop must have lowered to a while, not 720 unrolled steps
    assert "while" in text


def test_hlo_text_parses_back(outdir):
    """The emitted text must parse back through XLA's HLO text parser — the
    same parser `HloModuleProto::from_text_file` uses on the Rust side (the
    full compile+execute round trip is covered by rust/tests/runtime_*.rs)."""
    from jax._src.lib import xla_client as xc

    for name in ("shift_mc.hlo.txt", "shift_waveform.hlo.txt"):
        with open(os.path.join(outdir, name)) as f:
            text = f.read()
        m = xc._xla.hlo_module_from_text(text)
        # parsing reassigns instruction ids; module must be non-trivial
        assert len(m.as_serialized_hlo_module_proto()) > 1000


def test_hlo_entry_params(outdir):
    with open(os.path.join(outdir, "shift_waveform.hlo.txt")) as f:
        text = f.read()
    assert f"f32[1,{cm.N_PARAMS}]" in text
    assert f"f32[1,{model.waveform_len()},5]" in text
