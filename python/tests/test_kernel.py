"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps batch shapes, tile sizes, parameter perturbations, data
patterns, and integration configs; every case must match kernels/ref.py to
float32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitline, common as cm, ref

ATOL = 2e-5


def nominal(batch, bit=1, vdd=1.2):
    return ref.nominal_params_22nm(batch=batch, bit=bit, vdd=vdd)


def assert_matches_ref(p, tile, cfg=None):
    o_ref = np.asarray(ref.shift_transient_ref(p, cfg))
    o_ker = np.asarray(bitline.shift_transient(p, cfg, tile=tile))
    np.testing.assert_allclose(o_ref, o_ker, atol=ATOL)


class TestKernelVsRef:
    def test_nominal_bit1(self):
        assert_matches_ref(nominal(64, bit=1), tile=64)

    def test_nominal_bit0(self):
        assert_matches_ref(nominal(64, bit=0), tile=64)

    def test_multi_tile_grid(self):
        p = nominal(256)
        p[128:, cm.V_SRC0] = 0.0
        assert_matches_ref(p, tile=64)

    def test_tile_equals_batch(self):
        assert_matches_ref(nominal(128), tile=128)

    def test_batch_not_multiple_of_tile_raises(self):
        with pytest.raises(ValueError):
            bitline.shift_transient(nominal(100), tile=64)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        spread=st.floats(0.01, 0.25),
        bit=st.integers(0, 1),
    )
    def test_random_process_variation(self, seed, spread, bit):
        rng = np.random.default_rng(seed)
        p = nominal(64, bit=bit)
        # multiplicative perturbation of the physical parameters
        phys = [cm.C_SRC, cm.C_MIG, cm.C_DST, cm.C_BLA, cm.C_BLB,
                cm.R_SRC, cm.R_MIG_A, cm.R_MIG_B, cm.R_DST, cm.T_RISE]
        for idx in phys:
            p[:, idx] *= rng.uniform(1 - spread, 1 + spread, 64).astype(np.float32)
        p[:, cm.OFF_A] = rng.normal(0, 0.03, 64).astype(np.float32)
        p[:, cm.OFF_B] = rng.normal(0, 0.03, 64).astype(np.float32)
        assert_matches_ref(p, tile=32)

    @settings(max_examples=10, deadline=None)
    @given(
        log2_batch=st.integers(5, 9),
        log2_tile=st.integers(4, 7),
    )
    def test_shape_sweep(self, log2_batch, log2_tile):
        batch, tile = 2**log2_batch, 2**log2_tile
        if batch % tile:
            return
        p = nominal(batch)
        p[::3, cm.V_SRC0] = 0.0
        assert_matches_ref(p, tile=tile)

    @settings(max_examples=8, deadline=None)
    @given(
        vdd=st.floats(1.0, 3.3),
        trise=st.floats(0.3e-9, 2.0e-9),
    )
    def test_tech_node_voltage_sweep(self, vdd, trise):
        p = nominal(32, vdd=vdd)
        p[:, cm.T_RISE] = trise
        p[16:, cm.V_SRC0] = 0.0
        assert_matches_ref(p, tile=32)

    def test_alternate_integration_cfg(self):
        cfg = dict(dt=0.2e-9, t_sense=10e-9, t_act2=22e-9, t_end=40e-9)
        assert_matches_ref(nominal(64), tile=64, cfg=cfg)


class TestKernelPhysics:
    """Physical invariants of the kernel output (not just ref-match)."""

    def test_bit1_full_rail_writeback(self):
        out = np.asarray(bitline.shift_transient(nominal(32, bit=1), tile=32))
        vdd = 1.2
        assert (out[:, cm.V_DST_F] > 0.95 * vdd).all()
        assert (out[:, cm.V_MIG_F] > 0.95 * vdd).all()
        assert (out[:, cm.SENSE_A] > 0.05).all()
        assert (out[:, cm.SENSE_B] > 0.05).all()

    def test_bit0_full_rail_writeback(self):
        out = np.asarray(bitline.shift_transient(nominal(32, bit=0), tile=32))
        assert (out[:, cm.V_DST_F] < 0.05).all()
        assert (out[:, cm.SENSE_A] < -0.05).all()

    def test_src_restored_after_copy(self):
        # RowClone restores the source row to full rail (non-destructive copy)
        out = np.asarray(bitline.shift_transient(nominal(32, bit=1), tile=32))
        assert (out[:, cm.V_SRC_F] > 0.95 * 1.2).all()

    def test_dst_overwritten_regardless_of_old_value(self):
        p = nominal(32, bit=1)
        p[:, cm.V_DST0] = 1.2  # dst previously held a '1'
        p[:16, cm.V_SRC0] = 0.0  # src holds '0' in half the trials
        out = np.asarray(bitline.shift_transient(p, tile=32))
        assert (out[:16, cm.V_DST_F] < 0.05).all()
        assert (out[16:, cm.V_DST_F] > 0.95 * 1.2).all()

    def test_large_offset_flips_sense(self):
        # an SA offset exceeding the charge-sharing margin must flip the read
        p = nominal(32, bit=1)
        p[:, cm.OFF_A] = 0.2  # >> ~92 mV margin
        out = np.asarray(bitline.shift_transient(p, tile=32))
        assert (out[:, cm.SENSE_A] < 0).all()
        assert (out[:, cm.V_DST_F] < 0.05).all()  # wrong value propagates

    def test_retention_droop_shrinks_margin(self):
        p_full = nominal(32, bit=1)
        p_droop = nominal(32, bit=1)
        p_droop[:, cm.V_SRC0] = 1.2 * 0.8
        m_full = np.asarray(bitline.shift_transient(p_full, tile=32))[:, cm.SENSE_A]
        m_droop = np.asarray(bitline.shift_transient(p_droop, tile=32))[:, cm.SENSE_A]
        assert (m_droop < m_full).all()
        assert (m_droop > 0).all()  # still reads correctly

    def test_margin_scales_with_cell_cap(self):
        p_small = nominal(32, bit=1)
        p_small[:, [cm.C_SRC, cm.C_MIG, cm.C_DST]] *= 0.5
        m_small = np.asarray(bitline.shift_transient(p_small, tile=32))[:, cm.SENSE_A]
        m_nom = np.asarray(bitline.shift_transient(nominal(32), tile=32))[:, cm.SENSE_A]
        assert (m_small < m_nom).all()
