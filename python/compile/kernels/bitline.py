"""L1 Pallas kernel: batched bitline-transient integrator.

The Monte-Carlo hot-spot of the reproduction: integrate the lumped-RC
migration-cell shift path (two AAP command windows) for a tile of
independent trials entirely on-chip.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the trial
batch; each tile's full ODE state (5 node voltages + 2 sense captures per
trial) stays resident in VMEM for the whole time loop, so HBM traffic is one
read of the 16-float parameter vector and one write of the 6-float result
per trial. All ops are elementwise VPU work — there is no matmul in the
physics, the roofline is parameter-streaming bandwidth.

Lowered with interpret=True (CPU PJRT cannot run Mosaic custom-calls); the
time loop is a `lax.fori_loop`, which lowers to an HLO while-loop and is
compiled, not re-traced.

Correctness oracle: kernels/ref.py (lax.scan formulation); pytest +
hypothesis sweep batch shapes and parameter ranges against it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as cm


def _kernel(params_ref, out_ref, *, dt, n_steps, k_sense, k_act2):
    p = params_ref[...]                      # (tile, N_PARAMS)

    c_src = p[:, cm.C_SRC]
    c_mig = p[:, cm.C_MIG]
    c_dst = p[:, cm.C_DST]
    c_bla = p[:, cm.C_BLA]
    c_blb = p[:, cm.C_BLB]
    r_src = p[:, cm.R_SRC]
    r_mig_a = p[:, cm.R_MIG_A]
    r_mig_b = p[:, cm.R_MIG_B]
    r_dst = p[:, cm.R_DST]
    vdd = p[:, cm.VDD]
    half = 0.5 * vdd
    inv_trise = 1.0 / jnp.maximum(p[:, cm.T_RISE], 1e-12)
    sa_gain = p[:, cm.SA_GAIN]
    off_a = p[:, cm.OFF_A]
    off_b = p[:, cm.OFF_B]

    t_act2 = k_act2 * dt
    fdt = jnp.float32(dt)

    def window(v_first, c_first, r_first, v_second, c_second, r_second,
               v_bl, c_bl, off):
        """One AAP window; returns (v_first, v_second, v_bl, sense_raw)."""
        zero = jnp.zeros_like(v_bl)

        def step(i, carry):
            v1, v2, vb, sense = carry
            t = i.astype(jnp.float32) * fdt
            # wordline conductance ramps
            g1 = jnp.clip(t * inv_trise, 0.0, 1.0) / r_first
            g2 = jnp.clip((t - t_act2) * inv_trise, 0.0, 1.0) / r_second
            i1 = g1 * (vb - v1)
            i2 = g2 * (vb - v2)
            sa_on = jnp.where(i >= k_sense, 1.0, 0.0).astype(jnp.float32)
            raw = vb - half - off
            i_sa = sa_on * sa_gain * raw * c_bl
            nv1 = v1 + fdt * i1 / c_first
            nv2 = v2 + fdt * i2 / c_second
            nvb = jnp.clip(vb + fdt * (-(i1 + i2) + i_sa) / c_bl, 0.0, vdd)
            sense = jnp.where(i == k_sense, raw, sense)
            return nv1, nv2, nvb, sense

        return jax.lax.fori_loop(
            0, n_steps, step, (v_first, v_second, v_bl, zero))

    # initial state
    v_src = p[:, cm.V_SRC0]
    v_mig = half
    v_dst = p[:, cm.V_DST0]

    # AAP 1: src -> migration cell (port A) across bitline A
    v_src, v_mig, _v_bla, sense_a = window(
        v_src, c_src, r_src, v_mig, c_mig, r_mig_a, half, c_bla, off_a)

    # inter-AAP precharge, then AAP 2: migration (port B) -> dst on bitline B
    v_mig, v_dst, v_blb, sense_b = window(
        v_mig, c_mig, r_mig_b, v_dst, c_dst, r_dst, half, c_blb, off_b)

    out_ref[...] = jnp.stack(
        [sense_a, sense_b, v_dst, v_mig, v_src, v_blb], axis=-1)


def shift_transient(params, cfg=None, tile=512):
    """Pallas-kernel shift transient: f32[batch, N_PARAMS] -> f32[batch, N_OUT].

    `batch` must be a multiple of `tile` (the VMEM trial-tile size)."""
    cfg = dict(cm.DEFAULT_CFG, **(cfg or {}))
    batch = params.shape[0]
    if batch % tile != 0:
        raise ValueError(f"batch {batch} not a multiple of tile {tile}")
    n_steps = cm.steps_per_aap(cfg)
    k_sense = cm.sense_step(cfg)
    k_act2 = int(round(cfg["t_act2"] / cfg["dt"]))

    kern = functools.partial(
        _kernel, dt=cfg["dt"], n_steps=n_steps,
        k_sense=k_sense, k_act2=k_act2)

    return pl.pallas_call(
        kern,
        grid=(batch // tile,),
        in_specs=[pl.BlockSpec((tile, cm.N_PARAMS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, cm.N_OUT), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, cm.N_OUT), jnp.float32),
        interpret=True,
    )(params.astype(jnp.float32))
