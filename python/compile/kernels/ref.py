"""Pure-jnp oracle for the bitline-transient kernel.

Implements the identical physics as kernels/bitline.py but with
`jax.lax.scan` over timesteps and no Pallas — this is the correctness
reference the Pallas kernel is pytest-checked against, and it doubles as the
waveform model (scan `ys` carry the full node-voltage trace).

Physics (per trial, explicit Euler):

  cell <-> bitline through a wordline-gated access conductance
      g(t) = ramp(t / t_rise) / R_on
      dV_cell = g (V_bl - V_cell) dt / C_cell
      dV_bl  += g (V_cell - V_bl) dt / C_bl

  latch-type sense amp, enabled at t_sense, regenerative about the
  offset-shifted metastable point, rail-clamped:
      dV_bl += sa_gain (V_bl - VDD/2 - off) dt        (then clip to [0, VDD])

AAP-1 connects src (from t=0) and migration-port-A (from t_act2) to bitline
A; AAP-2 connects migration-port-B (from t=0) and dst (from t_act2) to
bitline B. Between the two windows both bitlines are precharged to VDD/2.
"""

import jax
import jax.numpy as jnp

from . import common as cm


def _ramp(t, t_rise):
    return jnp.clip(t / jnp.maximum(t_rise, 1e-12), 0.0, 1.0)


def _window(cfg, wiring):
    """Build the scan body for one AAP window.

    wiring = dict(first=(cell_key, r_idx), second=(cell_key, r_idx),
                  bl=(bl_key, c_idx, off_idx))
    """
    dt = cfg["dt"]
    k_sense = cm.sense_step(cfg)
    t_act2 = cfg["t_act2"]

    (fc_key, fc_r), (sc_key, sc_r) = wiring["first"], wiring["second"]
    bl_key, bl_c, off_idx = wiring["bl"]

    def body(state_and_sense, i):
        state, sense_raw = state_and_sense
        p = state["_p"]
        t = i.astype(jnp.float32) * dt
        vdd = p[:, cm.VDD]
        half = 0.5 * vdd
        t_rise = p[:, cm.T_RISE]

        v_bl = state[bl_key]
        c_bl = p[:, bl_c]

        # first cell: wordline from t = 0
        g1 = _ramp(t, t_rise) / p[:, fc_r]
        v_c1 = state[fc_key]
        c_c1 = p[:, cm.C_SRC + {"v_src": 0, "v_mig": 1, "v_dst": 2}[fc_key]]
        i1 = g1 * (v_bl - v_c1)

        # second cell: wordline from t = t_act2
        g2 = _ramp(t - t_act2, t_rise) / p[:, sc_r]
        v_c2 = state[sc_key]
        c_c2 = p[:, cm.C_SRC + {"v_src": 0, "v_mig": 1, "v_dst": 2}[sc_key]]
        i2 = g2 * (v_bl - v_c2)

        # sense amp (regenerative, enabled at t >= t_sense)
        sa_on = (i >= k_sense).astype(jnp.float32)
        off = p[:, off_idx]
        i_sa = sa_on * p[:, cm.SA_GAIN] * (v_bl - half - off) * c_bl

        nv_c1 = v_c1 + dt * i1 / c_c1
        nv_c2 = v_c2 + dt * i2 / c_c2
        nv_bl = jnp.clip(
            v_bl + dt * (-(i1 + i2) + i_sa) / c_bl, 0.0, vdd)

        new_state = dict(state)
        new_state[fc_key] = nv_c1
        new_state[sc_key] = nv_c2
        new_state[bl_key] = nv_bl

        # capture raw sense-input value at the sense instant
        raw_now = v_bl - half - off
        sense_raw = jnp.where(i == k_sense, raw_now, sense_raw)

        trace = jnp.stack(
            [new_state["v_src"], new_state["v_mig"], new_state["v_dst"],
             new_state["v_bl_a"], new_state["v_bl_b"]], axis=-1)
        return (new_state, sense_raw), trace

    return body


def _run_window(state, p, cfg, wiring):
    n = cm.steps_per_aap(cfg)
    state = dict(state)
    state["_p"] = p
    body = _window(cfg, wiring)
    sense0 = jnp.zeros(p.shape[0], dtype=p.dtype)
    (state, sense_raw), trace = jax.lax.scan(
        body, (state, sense0), jnp.arange(n))
    del state["_p"]
    return state, sense_raw, trace


WIRING_AAP1 = dict(first=("v_src", cm.R_SRC), second=("v_mig", cm.R_MIG_A),
                   bl=("v_bl_a", cm.C_BLA, cm.OFF_A))
WIRING_AAP2 = dict(first=("v_mig", cm.R_MIG_B), second=("v_dst", cm.R_DST),
                   bl=("v_bl_b", cm.C_BLB, cm.OFF_B))


def _init_state(p):
    vdd = p[:, cm.VDD]
    return dict(
        v_src=p[:, cm.V_SRC0],
        v_mig=0.5 * vdd,      # migration cell precharge-equalized
        v_dst=p[:, cm.V_DST0],
        v_bl_a=0.5 * vdd,
        v_bl_b=0.5 * vdd,
    )


def shift_transient_ref(params, cfg=None):
    """Oracle: params f32[batch, N_PARAMS] -> f32[batch, N_OUT]."""
    cfg = dict(cm.DEFAULT_CFG, **(cfg or {}))
    p = params.astype(jnp.float32)
    state = _init_state(p)

    state, sense_a, _ = _run_window(state, p, cfg, WIRING_AAP1)
    # precharge between AAPs
    vdd = p[:, cm.VDD]
    state["v_bl_a"] = 0.5 * vdd
    state["v_bl_b"] = 0.5 * vdd
    state, sense_b, _ = _run_window(state, p, cfg, WIRING_AAP2)

    return jnp.stack(
        [sense_a, sense_b, state["v_dst"], state["v_mig"],
         state["v_src"], state["v_bl_b"]], axis=-1)


def shift_waveform_ref(params, cfg=None, stride=10):
    """Waveform model: params f32[batch, N_PARAMS] ->
    f32[batch, 2*steps_per_aap//stride, 5] node-voltage trace
    (v_src, v_mig, v_dst, v_bl_a, v_bl_b), subsampled by `stride`."""
    cfg = dict(cm.DEFAULT_CFG, **(cfg or {}))
    p = params.astype(jnp.float32)
    state = _init_state(p)

    state, _, tr1 = _run_window(state, p, cfg, WIRING_AAP1)
    vdd = p[:, cm.VDD]
    state["v_bl_a"] = 0.5 * vdd
    state["v_bl_b"] = 0.5 * vdd
    state, _, tr2 = _run_window(state, p, cfg, WIRING_AAP2)

    trace = jnp.concatenate([tr1, tr2], axis=0)   # (2n, batch, 5)
    trace = trace[::stride]
    return jnp.transpose(trace, (1, 0, 2))        # (batch, T, 5)


def nominal_params_22nm(batch=1, bit=1, vdd=1.2):
    """Convenience nominal 22 nm parameter vector (Table 1 of the paper):
    C_cell = 25 fF, BL C/cell = 0.24 fF x 512 rows + 15 fF SA parasitic,
    t_rise = 0.5 ns."""
    import numpy as np
    p = np.zeros((batch, cm.N_PARAMS), dtype=np.float32)
    c_bl = 0.24e-15 * 512 + 15e-15
    p[:, cm.C_SRC] = 25e-15
    p[:, cm.C_MIG] = 25e-15
    p[:, cm.C_DST] = 25e-15
    p[:, cm.C_BLA] = c_bl
    p[:, cm.C_BLB] = c_bl
    p[:, cm.R_SRC] = 15e3
    p[:, cm.R_MIG_A] = 15e3
    p[:, cm.R_MIG_B] = 15e3
    p[:, cm.R_DST] = 15e3
    p[:, cm.VDD] = vdd
    p[:, cm.T_RISE] = 0.5e-9
    p[:, cm.SA_GAIN] = 2.0e9
    p[:, cm.OFF_A] = 0.0
    p[:, cm.OFF_B] = 0.0
    p[:, cm.V_SRC0] = vdd if bit else 0.0
    p[:, cm.V_DST0] = 0.0
    return p
