"""Shared constants for the bitline-transient circuit model.

This is the LTSPICE substitute of the reproduction: a lumped-RC model of the
migration-cell shift path (one bit travelling src cell -> bitline A ->
migration cell -> bitline B -> dst cell across two AAP command windows).

Parameter vector layout (per Monte-Carlo trial, f32[N_PARAMS]) — all SI:

  index  name       unit  meaning
  -----  ---------  ----  -------------------------------------------------
  0      C_SRC      F     source cell storage capacitance
  1      C_MIG      F     migration cell storage capacitance
  2      C_DST      F     destination cell storage capacitance
  3      C_BLA      F     bitline A total capacitance (per-cell C x rows + SA)
  4      C_BLB      F     bitline B total capacitance
  5      R_SRC      Ohm   src access transistor on-resistance
  6      R_MIG_A    Ohm   migration cell port-A on-resistance
  7      R_MIG_B    Ohm   migration cell port-B on-resistance
  8      R_DST      Ohm   dst access transistor on-resistance
  9      VDD        V     array supply
  10     T_RISE     s     wordline rise time (conductance ramp)
  11     SA_GAIN    1/s   sense-amp regeneration rate
  12     OFF_A      V     input-referred SA offset, bitline A
  13     OFF_B      V     input-referred SA offset, bitline B
  14     V_SRC0     V     initial src cell voltage (bit value + retention droop)
  15     V_DST0     V     initial dst cell voltage (pre-existing data)

Output vector layout (per trial, f32[N_OUT]):

  0      SENSE_A    V     (v_blA - vdd/2 - offA) at the AAP-1 sense instant
  1      SENSE_B    V     (v_blB - vdd/2 - offB) at the AAP-2 sense instant
  2      V_DST_F    V     final dst cell voltage (post write-back)
  3      V_MIG_F    V     final migration cell voltage
  4      V_SRC_F    V     final src cell voltage (restore check)
  5      V_BLB_F    V     final bitline B voltage

Classification (pass/fail per the paper's Section 4.2 criteria) happens on
the Rust side; the kernel is purely physical.
"""

N_PARAMS = 16
N_OUT = 6

# param indices
C_SRC, C_MIG, C_DST, C_BLA, C_BLB = 0, 1, 2, 3, 4
R_SRC, R_MIG_A, R_MIG_B, R_DST = 5, 6, 7, 8
VDD, T_RISE, SA_GAIN, OFF_A, OFF_B, V_SRC0, V_DST0 = 9, 10, 11, 12, 13, 14, 15

# output indices
SENSE_A, SENSE_B, V_DST_F, V_MIG_F, V_SRC_F, V_BLB_F = 0, 1, 2, 3, 4, 5

# Default integration config. One AAP window is modelled over tRAS-like 36 ns:
# wordline-1 ramp from t=0, sense enable at T_SENSE, second ACT at T_ACT2,
# wordlines drop / precharge at the end of the window.
DEFAULT_CFG = dict(
    dt=0.1e-9,        # explicit-Euler step (paper's LTSPICE used 1 ns; we use
                      # 0.1 ns because the cell-side tau R_on*C_cell ~ 0.4 ns)
    t_sense=8.0e-9,   # SA enable after charge sharing settles
    t_act2=20.0e-9,   # second ACT of the AAP (destination row)
    t_end=36.0e-9,    # tRAS window
)


def steps_per_aap(cfg) -> int:
    return int(round(cfg["t_end"] / cfg["dt"]))


def sense_step(cfg) -> int:
    return int(round(cfg["t_sense"] / cfg["dt"]))
