"""L2: the JAX circuit-validation model, composed from the L1 Pallas kernel.

Two entry points, both AOT-lowered by aot.py and executed from the Rust
coordinator via PJRT (Python is never on the request path):

  * `shift_mc`      — Monte-Carlo batch: f32[MC_BATCH, N_PARAMS] parameter
                      vectors in, f32[MC_BATCH, N_OUT] physical results out.
                      Parameter perturbation (process variation draws) and
                      pass/fail classification live on the Rust side; this
                      graph is pure physics.
  * `shift_waveform`— single-trial full node-voltage trace for validation
                      plots and the §4.2 signal-integrity checks.

The shapes are fixed at AOT time (PJRT executables are monomorphic); the
Rust Monte-Carlo harness loops whole MC_BATCH-sized batches and handles the
ragged tail by padding with nominal vectors.
"""

import jax
import jax.numpy as jnp

from .kernels import bitline, common as cm
from .kernels import ref as kref

# AOT shapes — keep in sync with artifacts/manifest.json (written by aot.py)
# and rust/src/runtime/artifacts.rs.
MC_BATCH = 8192
MC_TILE = 512
WAVE_STRIDE = 10


def shift_mc(params):
    """Monte-Carlo physics batch. params: f32[MC_BATCH, N_PARAMS]."""
    return (bitline.shift_transient(params, tile=MC_TILE),)


def shift_waveform(params):
    """Full trace for one trial. params: f32[1, N_PARAMS] ->
    f32[1, T, 5] with T = 2*steps_per_aap/WAVE_STRIDE."""
    return (kref.shift_waveform_ref(params, stride=WAVE_STRIDE),)


def waveform_len():
    return 2 * cm.steps_per_aap(cm.DEFAULT_CFG) // WAVE_STRIDE


def mc_example_args():
    return (jax.ShapeDtypeStruct((MC_BATCH, cm.N_PARAMS), jnp.float32),)


def waveform_example_args():
    return (jax.ShapeDtypeStruct((1, cm.N_PARAMS), jnp.float32),)
