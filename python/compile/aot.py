"""AOT bridge: lower the L2 model to HLO *text* for the Rust runtime.

HLO text (not `.serialize()`d HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits:
  shift_mc.hlo.txt        Monte-Carlo physics batch  (f32[8192,16] -> f32[8192,6])
  shift_waveform.hlo.txt  single-trial waveform      (f32[1,16] -> f32[1,T,5])
  manifest.json           shapes + config the Rust side validates against
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import common as cm


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(fn, example_args, path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    emit(model.shift_mc, model.mc_example_args(),
         os.path.join(args.out, "shift_mc.hlo.txt"))
    emit(model.shift_waveform, model.waveform_example_args(),
         os.path.join(args.out, "shift_waveform.hlo.txt"))

    manifest = {
        "format": "hlo-text",
        "return_tuple": True,
        "n_params": cm.N_PARAMS,
        "n_out": cm.N_OUT,
        "mc_batch": model.MC_BATCH,
        "mc_tile": model.MC_TILE,
        "waveform_len": model.waveform_len(),
        "waveform_nodes": 5,
        "cfg": cm.DEFAULT_CFG,
        "steps_per_aap": cm.steps_per_aap(cm.DEFAULT_CFG),
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest  {mpath}")


if __name__ == "__main__":
    main()
